"""repro.check analyzer tests: seeded defects, clean repo, CLI report.

The three seeded-defect fixtures (check/fixtures.py) each violate
exactly one kernel contract and must produce exactly that rule ID —
they are the proof the analyzer would catch a real regression.  The
clean-repo runs pin the acceptance criterion (`--strict` exits 0) per
pass, so a regression names the pass that broke.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import pytest

from repro.check import bounds, fixtures, jaxpr_audit, lint
from repro.check import registry_audit, vmem
from repro.check.findings import RULES, Finding
from repro.check.__main__ import run_all
from repro.tune import bench_check

_silent = lambda s: None  # noqa: E731 — quiet pass logs in tests


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# Seeded defects: each fires exactly its own rule
# ---------------------------------------------------------------------------

def test_oob_index_map_fixture_fires_b001():
    assert rules_of(fixtures.audit_oob_fixture()) == ["REPRO-B001"]


def test_quadratic_residual_fixture_fires_j001():
    assert rules_of(
        fixtures.audit_quadratic_residual_fixture()) == ["REPRO-J001"]


def test_unguarded_bf16_fixture_fires_j002():
    assert rules_of(fixtures.audit_bf16_fixture()) == ["REPRO-J002"]


def test_dropped_tail_grid_fires_b002():
    """A grid one step short of the extent drops the last output block."""
    import jax
    from jax.experimental import pallas as pl

    def short_copy(x, block=16):
        return pl.pallas_call(
            fixtures._copy_kernel,
            grid=(x.shape[0] // block - 1,),  # one block short
            in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)

    with bounds.record_launches() as launches:
        short_copy(jnp.zeros((64,), jnp.float32))
    findings = [f for la in launches for f in bounds.check_launch(la)]
    assert rules_of(findings) == ["REPRO-B002"]


def test_partial_block_fires_b003():
    """A block that does not divide the extent is flagged."""
    import jax
    from jax.experimental import pallas as pl

    def ragged_copy(x, block=24):
        return pl.pallas_call(
            fixtures._copy_kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        )(x)

    with bounds.record_launches() as launches:
        ragged_copy(jnp.zeros((60,), jnp.float32))
    findings = [f for la in launches for f in bounds.check_launch(la)]
    assert "REPRO-B003" in rules_of(findings)


# ---------------------------------------------------------------------------
# Clean repo: every pass returns zero findings
# ---------------------------------------------------------------------------

def test_registry_audit_clean():
    findings, coverage = registry_audit.run(log=_silent)
    assert findings == []
    assert coverage[0]["families"] == list(registry_audit.FAMILIES)


def test_lint_clean():
    findings, _ = lint.run(log=_silent)
    assert findings == [], [str(f) for f in findings]


def test_vmem_clean():
    findings, coverage = vmem.run(log=_silent)
    assert findings == []
    assert coverage[0]["cells"] > 0


def test_bounds_clean_all_families():
    findings, coverage = bounds.run(log=_silent)
    assert findings == [], [str(f) for f in findings]
    assert {c["family"] for c in coverage} == set(bounds.DRIVERS)


def test_jaxpr_clean_and_covers_registry():
    findings, coverage = jaxpr_audit.run(log=_silent)
    assert findings == [], [str(f) for f in findings]
    # acceptance: every family (incl. the fused decode ones) x every
    # registered impl audited
    from repro.check.registry_audit import FAMILIES
    from repro.kernels import ops
    audited = {(c["family"], c["impl"]) for c in coverage}
    expected = {(fam, impl)
                for fam in FAMILIES
                for impl in ops.kernel_names(fam)}
    assert audited == expected


# ---------------------------------------------------------------------------
# Analyzer plumbing
# ---------------------------------------------------------------------------

def test_finding_rejects_unknown_rule():
    with pytest.raises(KeyError):
        Finding("REPRO-X999", "nowhere", "nothing")


def test_report_shape(tmp_path):
    report = run_all(only={"registry", "lint"}, log=_silent)
    assert report["clean"] is True
    assert report["findings"] == []
    assert set(report["rules"]) == set(RULES)
    path = tmp_path / "CHECK.json"
    path.write_text(json.dumps(report))
    assert json.loads(path.read_text())["version"] == 1


def test_lint_suppression_comment():
    src = ("import time\n"
           "t = time.perf_counter()  # repro: ignore[REPRO-L001]\n")
    assert lint.lint_file("src/repro/fake.py", src) == []
    src_hot = "import time\nt = time.perf_counter()\n"
    assert rules_of(lint.lint_file("src/repro/fake.py", src_hot)) \
        == ["REPRO-L001"]


def test_lint_interpret_default_l003():
    src = "def f(x, interpret=True):\n    return x\n"
    assert rules_of(lint.lint_file("src/repro/fake.py", src)) \
        == ["REPRO-L003"]
    # tests are exempt: interpret mode is their job
    assert lint.lint_file("tests/test_fake.py", src) == []


def test_lint_l004_percentile_math_in_serving():
    src = ("import numpy as np\n"
           "def p99(xs):\n"
           "    return np.percentile(xs, 99)\n")
    assert rules_of(lint.lint_file("src/repro/serve/fake.py", src)) \
        == ["REPRO-L004"]
    assert rules_of(lint.lint_file("src/repro/obs/fake.py", src)) \
        == ["REPRO-L004"]
    # the ONE sanctioned home is exempt, as is everything outside the
    # serving stack (and tests)
    assert lint.lint_file("src/repro/obs/metrics.py", src) == []
    assert lint.lint_file("src/repro/train/fake.py", src) == []
    assert lint.lint_file("tests/test_fake.py", src) == []


def test_lint_l004_sorted_rank_indexing():
    src = ("def p99(xs):\n"
           "    return sorted(xs)[int(0.99 * len(xs))]\n")
    assert rules_of(lint.lint_file("src/repro/serve/fake.py", src)) \
        == ["REPRO-L004"]
    # sorted() without indexing is fine (ordering, not percentiles)
    ok = "def f(xs):\n    return sorted(xs)\n"
    assert lint.lint_file("src/repro/serve/fake.py", ok) == []


def test_lint_l004_statistics_import():
    src = "from statistics import median\n"
    assert rules_of(lint.lint_file("src/repro/obs/fake.py", src)) \
        == ["REPRO-L004"]
    assert lint.lint_file("src/repro/kernels/fake.py", src) == []


def test_lint_l004_time_in_serving_fires_both_rules():
    # time.* inside serve/ breaks two contracts at once: the repo-wide
    # timer rule (L001) and the serving-observability clock (L004)
    src = "import time\nt = time.perf_counter()\n"
    assert rules_of(lint.lint_file("src/repro/serve/fake.py", src)) \
        == ["REPRO-L001", "REPRO-L004"]
    # monotonic escapes L001's narrow ban but not the serving rule
    src_mono = "import time\nt = time.monotonic()\n"
    assert rules_of(lint.lint_file("src/repro/serve/fake.py",
                                   src_mono)) == ["REPRO-L004"]
    assert rules_of(lint.lint_file("src/repro/train/fake.py",
                                   src_mono)) == []


def test_vmem_flags_oversized_cache_entry(tmp_path):
    from repro.tune.cache import TuningCache
    cache = TuningCache(path=str(tmp_path / "tune_cache.json"))
    cache.put("softmax", "pallas", "fwd",
              {"b": 1, "h": 2, "hkv": 2, "n": 1024, "d": 4096},
              jnp.float32, {"block_q": 512, "block_k": 512})
    path = cache.save()
    findings = vmem.check_cache_file(path)
    assert rules_of(findings) == ["REPRO-V002"]


# ---------------------------------------------------------------------------
# bench_check best-cell validation (satellite)
# ---------------------------------------------------------------------------

def _sweep_doc(best_ms):
    roof = {"t_roofline_s": 1e-3, "achieved_frac": None}
    cand = {"tiles": {"chunk": 64}, "median_ms": 1.0, "roofline": roof}
    return {"sweeps": [{"candidates": [cand],
                        "best": {"tiles": {"chunk": 64},
                                 "median_ms": best_ms,
                                 "roofline": roof}}]}


def test_bench_check_accepts_true_best():
    assert bench_check.check_doc(_sweep_doc(1.0), "doc") == []


def test_bench_check_rejects_fake_best():
    errors = bench_check.check_doc(_sweep_doc(2.0), "doc")
    assert any("not the candidate minimum" in e for e in errors)


def test_bench_check_requires_best():
    doc = _sweep_doc(1.0)
    del doc["sweeps"][0]["best"]
    errors = bench_check.check_doc(doc, "doc")
    assert any("missing best cell" in e for e in errors)
