"""Property-based tests (hypothesis) for the system's invariants.

Invariants of normalized linear attention (paper Eqs. 4-9, 22):
  1. constant-value invariance — rows of the attention matrix sum to 1,
     so v_n = c for all n implies o_i = c exactly;
  2. causality — perturbing tokens > t never changes outputs <= t;
  3. scale invariance — with Eq. 22 normalization, rescaling any q_i or
     k_i row leaves the output unchanged;
  4. batch/head permutation equivariance;
  5. chunked == quadratic oracle for arbitrary shapes;
  6. decode chain == prefill for arbitrary split points.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package "
                         "(pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import chunked
from repro.core.linear_attention import LACfg, la_attention
from repro.core.numerics import l2_normalize
from repro.kernels import ops, ref

_settings = settings(max_examples=20, deadline=None)

dims = st.tuples(
    st.integers(1, 3),                    # B
    st.sampled_from([1, 2, 4]),           # Hkv
    st.integers(1, 4),                    # group multiplier
    st.integers(1, 70),                   # N
    st.sampled_from([4, 8, 16, 32]),      # D
    st.sampled_from([8, 16, 128]),        # chunk
)


def _qkv(b, hkv, g, n, d, seed):
    h = hkv * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = l2_normalize(jax.random.normal(ks[0], (b, h, n, d)))
    k = l2_normalize(jax.random.normal(ks[1], (b, hkv, n, d)))
    v = jax.random.normal(ks[2], (b, hkv, n, d))
    return q, k, v


@_settings
@given(dims, st.integers(0, 2**31 - 1))
def test_matches_oracle(dims_, seed):
    b, hkv, g, n, d, c = dims_
    q, k, v = _qkv(b, hkv, g, n, d, seed)
    o, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    o_ref = ref.la_ref(q, k, v, 1.0, 1.0, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=5e-4, atol=5e-4)


@_settings
@given(dims, st.integers(0, 2**31 - 1),
       st.floats(-3, 3, allow_nan=False))
def test_constant_value_invariance(dims_, seed, const):
    b, hkv, g, n, d, c = dims_
    q, k, _ = _qkv(b, hkv, g, n, d, seed)
    v = jnp.full((b, hkv, n, d), const, jnp.float32)
    o, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    np.testing.assert_allclose(np.asarray(o), const, rtol=1e-4, atol=1e-4)


@_settings
@given(dims, st.integers(0, 2**31 - 1), st.data())
def test_causality(dims_, seed, data):
    b, hkv, g, n, d, c = dims_
    q, k, v = _qkv(b, hkv, g, n, d, seed)
    t = data.draw(st.integers(0, n - 1))
    o1, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    # perturb all tokens strictly after t
    noise = jax.random.normal(jax.random.PRNGKey(seed ^ 0xabc),
                              (b, hkv, n - 1 - t, d))
    k2 = k.at[:, :, t + 1:].add(noise)
    v2 = v.at[:, :, t + 1:].add(noise * 2)
    o2, _, _ = chunked.la_fwd_chunked(q, k2, v2, 1.0, 1.0, chunk=c)
    np.testing.assert_allclose(np.asarray(o1[:, :, :t + 1]),
                               np.asarray(o2[:, :, :t + 1]),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(dims, st.integers(0, 2**31 - 1),
       st.floats(0.1, 10, allow_nan=False))
def test_qk_scale_invariance(dims_, seed, scale):
    """Eq. 22 row normalization cancels any per-row rescaling."""
    b, hkv, g, n, d, c = dims_
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = hkv * g
    q = jax.random.normal(ks[0], (b, h, n, d))
    k = jax.random.normal(ks[1], (b, hkv, n, d))
    v = jax.random.normal(ks[2], (b, hkv, n, d))
    cfg = LACfg(chunk=c, backend="xla")
    o1 = la_attention(q, k, v, cfg)
    o2 = la_attention(q * scale, k * scale, v, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-3, atol=1e-3)


@_settings
@given(dims, st.integers(0, 2**31 - 1))
def test_head_permutation_equivariance(dims_, seed):
    b, hkv, g, n, d, c = dims_
    q, k, v = _qkv(b, hkv, g, n, d, seed)
    perm = np.asarray(
        jax.random.permutation(jax.random.PRNGKey(seed ^ 0x5), hkv))
    o, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    # permute KV heads and the matching query groups
    qg = q.reshape(b, hkv, g, n, d)[:, perm].reshape(b, hkv * g, n, d)
    o2, _, _ = chunked.la_fwd_chunked(qg, k[:, perm], v[:, perm], 1.0, 1.0,
                                      chunk=c)
    og = o.reshape(b, hkv, g, n, d)[:, perm].reshape(b, hkv * g, n, d)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(og),
                               rtol=1e-5, atol=1e-5)


@_settings
@given(dims, st.integers(0, 2**31 - 1), st.data())
def test_decode_equals_prefill_any_split(dims_, seed, data):
    b, hkv, g, n, d, c = dims_
    q, k, v = _qkv(b, hkv, g, n, d, seed)
    split = data.draw(st.integers(1, n))
    o_full, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    _, stt = ops.la_prefill(q[:, :, :split], k[:, :, :split],
                            v[:, :, :split], 1.0, 1.0, c)
    for i in range(split, min(split + 3, n)):
        stt, o_i = chunked.la_decode_step(stt, q[:, :, i], k[:, :, i],
                                          v[:, :, i], 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(o_i),
                                   np.asarray(o_full[:, :, i]),
                                   rtol=5e-5, atol=5e-5)


@_settings
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 50),
       st.sampled_from([4, 8, 16]), st.integers(0, 2**31 - 1))
def test_gradient_matches_oracle(b, h, n, d, seed):
    q, k, v = _qkv(b, h, 1, n, d, seed)
    def f_c(q, k, v):
        return jnp.sum(jnp.cos(ops.la_causal(q, k, v, 1.0, 1.0, 16, "xla")))
    def f_r(q, k, v):
        return jnp.sum(jnp.cos(ref.la_ref(q, k, v, 1.0, 1.0, causal=True)))
    g1 = jax.grad(f_c, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a_, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-3, atol=1e-3)
