"""Linear-attention kernel correctness: xla chunked scan + Pallas
(interpret mode) against the pure-jnp quadratic oracle, across
shape/dtype sweeps; analytic backward against autodiff of the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_impl_parity
from repro.core import chunked
from repro.core.numerics import l2_normalize
from repro.kernels import linear_attention as pk
from repro.kernels import ops, ref

SHAPES = [
    # (B, H, Hkv, N, D, chunk)
    (1, 1, 1, 8, 4, 4),
    (2, 4, 4, 64, 16, 16),
    (2, 4, 2, 100, 32, 32),      # GQA + ragged N
    (1, 8, 1, 96, 64, 128),      # MQA, chunk > N
    (3, 6, 3, 33, 8, 16),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _make(b, h, hkv, n, d, dtype, key=0, normalize=True):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (b, h, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, n, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, n, d), jnp.float32)
    if normalize:  # paper Eq. 22 keeps the denominator positive
        q, k = l2_normalize(q), l2_normalize(k)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fwd_impl_parity(shape, dtype):
    """Every registered linear impl (xla scan, pallas-interpret kernel,
    quadratic oracle) agrees on the forward, and the chunked normalizer
    stays positive (consolidated from the old per-impl vs-ref tests)."""
    b, h, hkv, n, d, c = shape
    q, k, v = _make(b, h, hkv, n, d, dtype)
    o_ref = ref.la_ref(q, k, v, 1.0, 1.0, causal=True)
    assert_impl_parity(
        lambda impl: ops.la_causal(q, k, v, 1.0, 1.0, c, impl),
        ["xla", "pallas_interpret", "ref"], **_tol(dtype),
        label=f"la fwd {shape}")
    o, g, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))
    assert bool(jnp.all(g[:, :, 1:] > 0)), "normalizer must stay positive"


@pytest.mark.parametrize("ab", [(1.0, 1.0), (0.5, 2.0), (2.0, 0.25)])
def test_general_kernel_coeffs(ab):
    """f(x) = a + b x for learnable (a, b), paper §2.2."""
    a, b_ = ab
    q, k, v = _make(2, 4, 2, 40, 16, jnp.float32)
    o_ref = ref.la_ref(q, k, v, a, b_, causal=True)
    o, _, _ = chunked.la_fwd_chunked(q, k, v, a, b_, chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), rtol=2e-5,
                               atol=2e-5)
    o_pl, _ = pk.la_fwd_pallas(q, k, v, a, b_, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_backward_vs_autodiff_oracle(shape):
    """Paper Eqs. 19-21: the analytic gradient must equal autodiff of the
    quadratic reference."""
    b, h, hkv, n, d, c = shape
    q, k, v = _make(b, h, hkv, n, d, jnp.float32)

    def loss_custom(q, k, v):
        return jnp.sum(jnp.sin(ops.la_causal(q, k, v, 1.0, 1.0, c, "xla")))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.la_ref(q, k, v, 1.0, 1.0, causal=True)))

    g1 = jax.grad(loss_custom, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a_, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", SHAPES)
def test_backward_pallas_vs_chunked(shape):
    b, h, hkv, n, d, c = shape
    q, k, v = _make(b, h, hkv, n, d, jnp.float32)
    o, g = pk.la_fwd_pallas(q, k, v, 1.0, 1.0, c, interpret=True)
    om = jax.random.normal(jax.random.PRNGKey(7), o.shape)
    dq1, dk1, dv1 = pk.la_bwd_pallas(q, k, v, o, g, om, 1.0, 1.0, c,
                                     interpret=True)
    dq2, dk2, dv2 = chunked.la_bwd_chunked(q, k, v, o, g, om, 1.0, 1.0, c)
    for a_, b_ in ((dq1, dq2), (dk1, dk2), (dv1, dv2)):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_residual_memory_is_linear():
    """The custom vjp must store only {q,k,v,o,g} — O(N D), not O(N D^2)
    (the paper's §3.2 memory contract)."""
    b, h, n, d = 1, 2, 64, 16
    q, k, v = _make(b, h, h, n, d, jnp.float32)
    _, vjp = jax.vjp(lambda *a: ops.la_causal(*a, 1.0, 1.0, 16, "xla"),
                     q, k, v)
    leaves = jax.tree.leaves(vjp)
    res_elems = sum(x.size for x in leaves if hasattr(x, "size"))
    # q,k,v,o: 4*(B*H*N*D); g: B*H*N  (plus small constants)
    budget = 4 * b * h * n * d + b * h * n
    assert res_elems <= budget * 1.5, (res_elems, budget)


def test_noncausal_vs_ref():
    q, k, v = _make(2, 4, 2, 48, 16, jnp.float32)
    o_ref = ref.la_ref(q, k, v, 1.0, 1.0, causal=False)
    o = chunked.la_noncausal(q, k, v, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_prefill_decode_chain_matches_full():
    b, h, hkv, n, d = 2, 4, 2, 40, 16
    q, k, v = _make(b, h, hkv, n, d, jnp.float32)
    o_full, _, _ = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=16)
    o_pre, st = ops.la_prefill(q[:, :, :30], k[:, :, :30], v[:, :, :30],
                               1.0, 1.0, 16)
    np.testing.assert_allclose(np.asarray(o_pre),
                               np.asarray(o_full[:, :, :30]),
                               rtol=1e-5, atol=1e-5)
    for i in range(30, n):
        st, o_i = chunked.la_decode_step(st, q[:, :, i], k[:, :, i],
                                         v[:, :, i], 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(o_i),
                                   np.asarray(o_full[:, :, i]),
                                   rtol=1e-5, atol=1e-5)


def test_state_size_independent_of_context():
    """Paper's deployment claim: decode state is O(D^2), not O(N)."""
    st = chunked.init_state(2, 4, 64)
    assert st.s.shape == (2, 4, 64, 65)
    assert st.p.shape == (2, 4, 65)


def test_chunk_size_invariance():
    q, k, v = _make(2, 4, 2, 96, 16, jnp.float32)
    outs = [chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)[0]
            for c in (8, 16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


def test_learnable_coefficients_gradients():
    """Paper §2.2: f(x) = a + b x with LEARNABLE (a, b) — the analytic
    da/db must match autodiff of the oracle, and a·da + b·db == 0 (the
    output depends only on a/b)."""
    q, k, v = _make(2, 4, 2, 50, 16, jnp.float32)
    a, b_ = jnp.float32(0.8), jnp.float32(1.3)

    def loss_c(q, k, v, a, b_):
        return jnp.sum(jnp.sin(
            ops.la_causal_learnable(q, k, v, a, b_, 16, "xla")))

    def loss_r(q, k, v, a, b_):
        return jnp.sum(jnp.sin(ref.la_ref(q, k, v, a, b_, causal=True)))

    g1 = jax.grad(loss_c, argnums=(0, 1, 2, 3, 4))(q, k, v, a, b_)
    g2 = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(q, k, v, a, b_)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=5e-4, atol=5e-4)
    assert abs(float(a * g1[3] + b_ * g1[4])) < 1e-5


def test_learnable_coefficients_train_step(rng):
    """A model configured with learnable (a, b) trains and moves them."""
    import dataclasses
    from repro.configs.base import LACfg, TrainConfig
    from repro.configs.registry import get_config
    from repro.models import model as mdl
    from repro.optim import adamw
    from repro.train.step import build_train_step

    cfg = get_config("qwen2.5-3b", smoke=True)
    cfg = dataclasses.replace(
        cfg, la=dataclasses.replace(cfg.la, learnable_coeffs=True))
    params = mdl.init_params(cfg, rng)
    assert "la_a" in params["blocks"]["mixer"], "learnable coeffs missing"
    tc = TrainConfig(warmup_steps=0, total_steps=10, learning_rate=1e-2,
                     checkpoint_every=0)
    step = jax.jit(build_train_step(cfg, tc))
    opt = adamw.init(params)
    batch = {"tokens": jax.random.randint(rng, (2, 24), 0, cfg.vocab_size)}
    a0 = float(params["blocks"]["mixer"]["la_a"][0])
    for i in range(3):
        params, opt, m = step(params, opt, batch, i + 1)
        assert np.isfinite(float(m["loss"]))
    a1 = float(params["blocks"]["mixer"]["la_a"][0])
    assert a0 != a1, "learnable coefficient did not move"
