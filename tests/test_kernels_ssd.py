"""SSD (Mamba-2 / state-space duality) kernel correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_impl_parity
from repro.core import ssd as cssd
from repro.kernels import ops, ref
from repro.kernels import ssd as kssd

SHAPES = [
    (1, 2, 16, 8, 8, 8),
    (2, 4, 64, 16, 32, 16),
    (2, 3, 70, 16, 16, 32),   # ragged N
]


def _make(b, h, n, dk, dv, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, h, n, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, h, n, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, n, dv))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, n)))
    return q, k, v, ld


@pytest.mark.parametrize("shape", SHAPES)
def test_fwd_impl_parity(shape):
    """Every registered ssd impl (xla scan, pallas-interpret kernel,
    quadratic oracle) agrees on the forward (consolidated from the old
    per-impl vs-ref tests, through the registry entry point)."""
    b, h, n, dk, dv, c = shape
    q, k, v, ld = _make(b, h, n, dk, dv)
    assert_impl_parity(
        lambda impl: ops.ssd_causal(q, k, v, ld, c, impl),
        ["xla", "pallas_interpret", "ref"], rtol=2e-4, atol=2e-4,
        label=f"ssd fwd {shape}")
    o_ref = ref.ssd_ref(q, k, v, ld)
    o = kssd.ssd_fwd_pallas(q, k, v, ld, chunk=c, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_chunked():
    b, h, n, dk, dv = 2, 3, 24, 8, 8
    q, k, v, ld = _make(b, h, n, dk, dv)
    o_full, _ = cssd.ssd_fwd_chunked(q, k, v, ld, chunk=8)
    _, st = cssd.ssd_fwd_chunked(q[:, :, :16], k[:, :, :16], v[:, :, :16],
                                 ld[:, :, :16], chunk=8)
    for i in range(16, n):
        st, o_i = cssd.ssd_decode_step(st, q[:, :, i], k[:, :, i],
                                       v[:, :, i], ld[:, :, i])
        np.testing.assert_allclose(np.asarray(o_i),
                                   np.asarray(o_full[:, :, i]),
                                   rtol=1e-4, atol=1e-4)


def test_no_decay_reduces_to_unnormalized_la():
    """gamma == 1 (log_decay == 0) makes SSD == cumulative k v^T."""
    b, h, n, dk, dv = 1, 2, 20, 8, 8
    q, k, v, _ = _make(b, h, n, dk, dv)
    ld = jnp.zeros((b, h, n))
    o, _ = cssd.ssd_fwd_chunked(q, k, v, ld, chunk=8)
    # manual: o_t = q_t . sum_{i<=t} k_i v_i^T
    s = jnp.cumsum(k[..., :, None] * v[..., None, :], axis=2)  # wrong axis
    s = jnp.cumsum(jnp.einsum("bhnd,bhne->bhnde", k, v), axis=2)
    o_ref = jnp.einsum("bhnd,bhnde->bhne", q, s)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_gradients_flow():
    b, h, n, dk, dv = 1, 2, 32, 8, 8
    q, k, v, ld = _make(b, h, n, dk, dv)
    def loss(q, k, v, ld):
        o, _ = cssd.ssd_fwd_chunked(q, k, v, ld, chunk=8)
        return jnp.sum(o ** 2)
    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, ld)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.abs(g).max()) > 0


@pytest.mark.parametrize("shape", SHAPES)
def test_analytic_backward_vs_autodiff_oracle(shape):
    """Beyond-paper: the paper's analytic-backward discipline extended
    to the decay-gated mixer (core/ssd.py) — must equal autodiff of the
    quadratic SSD oracle, including the log-decay gradient."""
    b, h, n, dk, dv, c = shape
    q, k, v, ld = _make(b, h, n, dk, dv)

    def loss_custom(q, k, v, ld):
        return jnp.sum(jnp.sin(cssd.ssd_causal(q, k, v, ld, c)))

    def loss_ref(q, k, v, ld):
        return jnp.sum(jnp.sin(ref.ssd_ref(q, k, v, ld)))

    g1 = jax.grad(loss_custom, argnums=(0, 1, 2, 3))(q, k, v, ld)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(q, k, v, ld)
    for a_, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_analytic_backward_residuals_linear():
    """Residuals are {q, k, v, ld, o} — O(N D)."""
    b, h, n, dk, dv = 1, 2, 64, 8, 8
    q, k, v, ld = _make(b, h, n, dk, dv)
    _, vjp = jax.vjp(lambda *a: cssd.ssd_causal(*a, 16), q, k, v, ld)
    res = sum(x.size for x in jax.tree.leaves(vjp) if hasattr(x, "size"))
    budget = b * h * n * (2 * dk + 2 * dv + 1)
    assert res <= budget * 1.5, (res, budget)


def test_pallas_backward_vs_chunked():
    """The TPU backward kernel must match the XLA analytic backward for
    grouped and ungrouped q/k."""
    import jax.numpy as jnp
    from repro.kernels.ssd import ssd_bwd_pallas
    b, g, h, n, dk, dv = 2, 1, 4, 37, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (b, g, n, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, g, n, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, n, dv))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, n)))
    om = jax.random.normal(ks[4], (b, h, n, dv))
    o, _ = cssd.ssd_fwd_chunked(q, k, v, ld, chunk=16)
    ref_g = cssd.ssd_bwd_chunked(q, k, v, ld, o, om, chunk=16)
    out_g = ssd_bwd_pallas(q, k, v, ld, o, om, chunk=16, interpret=True)
    for a, b_ in zip(out_g, ref_g):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_grouped_fwd_matches_expanded():
    """Shared q/k (G=1) must equal the expanded per-head computation."""
    import jax.numpy as jnp
    b, g, h, n, dk, dv = 2, 1, 6, 40, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (b, g, n, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, g, n, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, n, dv))
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, n)))
    o_g, _ = cssd.ssd_fwd_chunked(q, k, v, ld, chunk=16)
    o_e, _ = cssd.ssd_fwd_chunked(jnp.repeat(q, h, 1), jnp.repeat(k, h, 1),
                                  v, ld, chunk=16)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_e),
                               rtol=1e-5, atol=1e-5)
