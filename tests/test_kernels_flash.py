"""Flash-attention (softmax pallas) kernel correctness: GQA-native
forward, per-slot q_offset continuation prefill, padded-row numerics,
and the flash v2 recomputation-based backward — all in interpret mode
against the XLA scan and the grouped quadratic oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_impl_parity
from repro.core.softmax import softmax_chunked
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bwd_pallas, \
    flash_attention_pallas

SHAPES = [(1, 2, 32, 16), (2, 4, 128, 32), (2, 2, 200, 64)]


def _qkv(seed, b, h, hkv, n, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = (jax.random.normal(ks[0], (b, h, n, d)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, n, d)) * 0.3).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, n, d)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("shape", SHAPES)
def test_flash_pallas_vs_ref(shape):
    b, h, n, d = shape
    q, k, v = _qkv(0, b, h, h, n, d)
    o = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                               interpret=True)
    o_ref = ref.softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("group", [2, 4])
@pytest.mark.parametrize("n", [48, 70])
def test_flash_pallas_gqa_native(group, n):
    """Grouped queries against UNEXPANDED (B, Hkv, N, D) keys/values —
    the KV BlockSpec indexes by head // group, no fold copy anywhere."""
    b, h, d = 2, 4, 16
    q, k, v = _qkv(1, b, h, h // group, n, d)
    o = flash_attention_pallas(q, k, v, block_q=16, block_k=32,
                               interpret=True)
    o_ref = ref.softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_lse_matches_oracle():
    """The returned logsumexp (the backward's residual) equals the
    quadratic oracle's row logsumexp."""
    b, h, n, d = 2, 2, 40, 16
    q, k, v = _qkv(2, b, h, h, n, d)
    _, lse = flash_attention_pallas(q, k, v, block_q=16, block_k=16,
                                    interpret=True, return_lse=True)
    s = jnp.einsum("bhid,bhjd->bhij", q, k) / d ** 0.5
    s = jnp.where(jnp.tril(jnp.ones((n, n), bool)), s, -jnp.inf)
    want = jax.scipy.special.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_q_offset_matches_xla_and_oracle():
    """Continuation prefill: window queries at per-slot absolute offsets
    against a populated KV cache must match the XLA q_offset scan AND a
    per-slot sliced oracle."""
    b, h, hkv, d, s_len, w = 2, 4, 2, 16, 64, 8
    offs = [17, 5]
    q_off = jnp.asarray(offs, jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    qw = jax.random.normal(ks[0], (b, h, w, d)) * 0.3
    kc = jnp.zeros((b, hkv, s_len, d))
    vc = jnp.zeros((b, hkv, s_len, d))
    for i, off in enumerate(offs):
        kc = kc.at[i, :, :off + w].set(
            jax.random.normal(jax.random.fold_in(ks[1], i),
                              (hkv, off + w, d)) * 0.3)
        vc = vc.at[i, :, :off + w].set(
            jax.random.normal(jax.random.fold_in(ks[2], i),
                              (hkv, off + w, d)))

    o = flash_attention_pallas(qw, kc, vc, block_q=8, block_k=16,
                               interpret=True, q_offset=q_off)
    o_xla = softmax_chunked(qw, kc, vc, causal=True, chunk=16,
                            q_offset=q_off)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_xla),
                               rtol=2e-5, atol=2e-5)
    for i, off in enumerate(offs):
        # slot alone: its window attends to exactly its cached prefix
        want = ref.softmax_ref(qw[i:i + 1], kc[i:i + 1, :, :off + w],
                               vc[i:i + 1, :, :off + w])
        np.testing.assert_allclose(np.asarray(o[i:i + 1]),
                                   np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"slot {i} offset {off}")


def test_flash_q_offset_through_registry():
    """ops.softmax_attention with q_offset on the pallas impl must run
    the flash kernel (no XLA fallback) and agree with the xla impl."""
    b, h, hkv, d, s_len, w = 2, 4, 2, 16, 48, 7
    q_off = jnp.asarray([13, 4], jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    qw = jax.random.normal(ks[0], (b, h, w, d)) * 0.3
    kc = jax.random.normal(ks[1], (b, hkv, s_len, d)) * 0.3
    vc = jax.random.normal(ks[2], (b, hkv, s_len, d))
    o_pl = ops.softmax_attention(qw, kc, vc, chunk=16,
                                 backend="pallas_interpret",
                                 q_offset=q_off)
    o_x = ops.softmax_attention(qw, kc, vc, chunk=16, backend="xla",
                                q_offset=q_off)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_x),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n", [37, 200])
def test_flash_padded_rows_no_nan(n):
    """Regression: n not a multiple of block_q pads query rows whose
    finalize used to divide by l == 0 — the guarded divide must keep the
    whole computation NaN-free (checked with jax_debug_nans) and the
    real rows exact."""
    b, h, d = 1, 2, 16
    q, k, v = _qkv(5, b, h, h, n, d)
    jax.config.update("jax_debug_nans", True)
    try:
        o, lse = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                        interpret=True, return_lse=True)
        dq, dk, dv = flash_attention_bwd_pallas(
            q, k, v, o, lse, jnp.ones_like(o), block_q=64, block_k=64,
            interpret=True)
    finally:
        jax.config.update("jax_debug_nans", False)
    assert np.isfinite(np.asarray(o)).all()
    assert np.isfinite(np.asarray(lse)).all()
    for g in (dq, dk, dv):
        assert np.isfinite(np.asarray(g)).all()
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(ref.softmax_ref(q, k, v)),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Backward (flash v2): gradient parity vs the XLA scan and the oracle
# ---------------------------------------------------------------------------

def _grads(fn, q, k, v, w):
    return jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) * w),
                    argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("group", [1, 4])
@pytest.mark.parametrize("n", [32, 45])
def test_flash_backward_parity(group, n):
    """softmax x pallas_interpret gradients == autodiff of the XLA scan
    == autodiff of the grouped oracle, across group sizes and odd N
    (the ref "impl" IS autodiff of the oracle through the registry)."""
    b, h, d = 2, 4, 16
    q, k, v = _qkv(6, b, h, h // group, n, d)
    w = jax.random.normal(jax.random.PRNGKey(7), q.shape)
    assert_impl_parity(
        lambda impl: _grads(lambda q, k, v: ops.softmax_attention(
            q, k, v, chunk=16, backend=impl), q, k, v, w),
        ["xla", "pallas_interpret", "ref"], rtol=2e-4, atol=2e-4,
        label=f"flash grads (g={group}, n={n})")


def test_flash_backward_unequal_blocks():
    """Regression: block_q != block_k must pad to a common multiple of
    both block sizes — flooring the grid used to drop whole KV blocks
    from dq and leave dk/dv rows unwritten."""
    b, h, n, d = 1, 2, 40, 16
    q, k, v = _qkv(11, b, h, h, n, d)
    w = jax.random.normal(jax.random.PRNGKey(12), q.shape)
    o, lse = flash_attention_pallas(q, k, v, block_q=32, block_k=16,
                                    interpret=True, return_lse=True)
    dq, dk, dv = flash_attention_bwd_pallas(q, k, v, o, lse, w,
                                            block_q=32, block_k=16,
                                            interpret=True)
    g_ref = _grads(lambda q, k, v: ref.softmax_ref(q, k, v), q, k, v, w)
    for name, a, b_ in zip(("dq", "dk", "dv"), (dq, dk, dv), g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4, err_msg=name)


def test_flash_backward_bf16():
    """bf16 inputs train through the flash custom vjp: grads stay close
    to the f32 oracle at bf16-appropriate tolerance."""
    b, h, group, n, d = 2, 4, 2, 40, 16
    q, k, v = _qkv(8, b, h, h // group, n, d, dtype=jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    g_pl = _grads(lambda q, k, v: ops.softmax_attention(
        q, k, v, chunk=16, backend="pallas_interpret"), q, k, v, w)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    g_ref = _grads(lambda q, k, v: ref.softmax_ref(q, k, v),
                   qf, kf, vf, w)
    for name, a, b_ in zip(("dq", "dk", "dv"), g_pl, g_ref):
        assert a.dtype == jnp.bfloat16, name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_),
                                   rtol=1e-1, atol=1e-1, err_msg=name)


def test_flash_residuals_are_linear_size():
    """The custom vjp stores {q, k, v, o, lse} — O(N D) — not the O(N^2)
    probability matrix autodiff of the oracle would keep."""
    b, h, n, d = 1, 2, 256, 16
    q, k, v = _qkv(10, b, h, h, n, d)
    _, vjp = jax.vjp(lambda q, k, v: ops.softmax_attention(
        q, k, v, chunk=64, backend="pallas_interpret"), q, k, v)
    res_bytes = sum(x.size * x.dtype.itemsize
                    for x in jax.tree.leaves(vjp) if hasattr(x, "size"))
    # 4 (N, D) tensors + one f32 (N,) row stat per head, with slack
    budget = 2 * (4 * b * h * n * d * 4 + b * h * n * 4)
    assert res_bytes <= budget, (res_bytes, budget)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_softmax_chunked_vs_ref(shape, causal):
    """The XLA online-softmax path used by the softmax model backend."""
    b, h, n, d = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, n, d)) * 0.3
    k = jax.random.normal(ks[1], (b, h // 2 or 1, n, d)) * 0.3
    v = jax.random.normal(ks[2], (b, h // 2 or 1, n, d))
    o = softmax_chunked(q, k, v, causal=causal, chunk=48)
    o_ref = ref.softmax_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
