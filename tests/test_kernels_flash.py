"""Flash-attention baseline kernel (softmax) correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.core.softmax import softmax_chunked

SHAPES = [(1, 2, 32, 16), (2, 4, 128, 32), (2, 2, 200, 64)]


@pytest.mark.parametrize("shape", SHAPES)
def test_flash_pallas_vs_ref(shape):
    b, h, n, d = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, n, d)) * 0.3
    k = jax.random.normal(ks[1], (b, h, n, d)) * 0.3
    v = jax.random.normal(ks[2], (b, h, n, d))
    o = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                               interpret=True)
    o_ref = ref.softmax_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("causal", [True, False])
def test_softmax_chunked_vs_ref(shape, causal):
    """The XLA online-softmax path used by the softmax model backend."""
    b, h, n, d = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, n, d)) * 0.3
    k = jax.random.normal(ks[1], (b, h // 2 or 1, n, d)) * 0.3
    v = jax.random.normal(ks[2], (b, h // 2 or 1, n, d))
    o = softmax_chunked(q, k, v, causal=causal, chunk=48)
    o_ref = ref.softmax_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
