"""Autotuning subsystem (repro.tune): cache keying and round-trip,
dispatch integration (byte-identical empty-cache fallback + tuned tile
resolution), tuned-vs-default numerical parity for every kernel family,
the sweep CLI end-to-end, and the timer/roofline helpers."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.analysis.roofline import attention_costs, kernel_roofline
from repro.kernels import ops
from repro.kernels.defaults import DEFAULT_TILES, default_tiles
from repro.tune.cache import TuningCache, make_key, shape_bucket, validate
from repro.tune.space import candidates, search_space, vmem_bytes_estimate
from repro.tune.timer import measure


@pytest.fixture(autouse=True)
def _no_cache_leak():
    """Every test starts and ends with no tuning cache installed."""
    prev = ops.set_tuning_cache(None)
    yield
    ops.set_tuning_cache(prev)


def _shape(b=1, h=4, hkv=2, n=100, d=16, **extra):
    return dict({"b": b, "h": h, "hkv": hkv, "n": n, "d": d}, **extra)


def _qkv(b=1, h=4, hkv=2, n=100, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, h, n, d)) * 0.3,
            jax.random.normal(ks[1], (b, hkv, n, d)) * 0.3,
            jax.random.normal(ks[2], (b, hkv, n, d)))


# ---------------------------------------------------------------------------
# cache: keying, round-trip, schema
# ---------------------------------------------------------------------------

def test_shape_bucket_pow2_on_b_and_n_only():
    assert shape_bucket(_shape(n=1000)) == shape_bucket(_shape(n=1024))
    assert shape_bucket(_shape(n=1025)) != shape_bucket(_shape(n=1024))
    assert shape_bucket(_shape(b=3)) == shape_bucket(_shape(b=4))
    # head counts and head_dim are exact, never bucketed
    assert shape_bucket(_shape(h=3)) != shape_bucket(_shape(h=4))
    assert shape_bucket(_shape(d=48)) != shape_bucket(_shape(d=64))


def test_make_key_separates_op_dtype_device():
    s = _shape()
    base = make_key("linear", "pallas", "fwd", s, jnp.float32, "tpu")
    assert make_key("linear", "pallas", "bwd", s, jnp.float32, "tpu") != base
    assert make_key("linear", "pallas", "fwd", s, jnp.bfloat16, "tpu") != base
    assert make_key("linear", "pallas", "fwd", s, jnp.float32, "cpu") != base
    with pytest.raises(ValueError):
        make_key("linear", "pallas", "fwdbwd", s, jnp.float32)


def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = TuningCache(path=path)
    s = _shape(n=1000)
    cache.put("linear", "pallas", "fwd", s, jnp.float32, {"chunk": 64},
              median_ms=1.25)
    cache.save()
    loaded = TuningCache.load(path)
    assert len(loaded) == 1
    # bucketing at lookup: n=1000 and n=1024 resolve the same entry
    for n in (1000, 1024, 513):
        hit = loaded.lookup("linear", "pallas", "fwd", _shape(n=n),
                            jnp.float32)
        assert hit == {"chunk": 64}
    assert loaded.lookup("linear", "pallas", "fwd", _shape(n=2048),
                         jnp.float32) is None
    assert loaded.lookup("linear", "pallas", "bwd", _shape(n=1000),
                         jnp.float32) is None


def test_load_missing_file_is_empty_cache(tmp_path):
    cache = TuningCache.load(str(tmp_path / "nope.json"))
    assert len(cache) == 0
    assert cache.lookup("linear", "xla", "fwd", _shape(), jnp.float32) is None


def test_validate_catches_corruption(tmp_path):
    cache = TuningCache(path=str(tmp_path / "c.json"))
    cache.put("gla", "pallas", "bwd", _shape(), jnp.float32, {"chunk": 32})
    doc = cache.to_doc()
    assert validate(doc) == []
    assert validate({"version": 99, "entries": {}})
    assert validate({"version": 1, "entries": {"k": {"tiles": {}}}})
    bad = json.loads(json.dumps(doc))
    key = next(iter(bad["entries"]))
    bad["entries"][key]["tiles"]["chunk"] = -1
    assert any("positive ints" in e for e in validate(bad))
    bad = json.loads(json.dumps(doc))
    bad["entries"]["wrong|key"] = bad["entries"].pop(key)
    assert any("does not match" in e for e in validate(bad))
    with pytest.raises(ValueError):
        path = str(tmp_path / "bad.json")
        with open(path, "w") as f:
            json.dump({"version": 99, "entries": {}}, f)
        TuningCache.load(path)


# ---------------------------------------------------------------------------
# search spaces
# ---------------------------------------------------------------------------

def test_search_space_matches_defaults_table():
    """Space parameter names == kernels/defaults.py keys per family
    (that is what dispatch can apply)."""
    for family in ("linear", "gla", "ssd"):
        assert set(search_space(family, "pallas")) == \
            set(DEFAULT_TILES[family])
    assert set(search_space("softmax", "pallas")) == \
        set(DEFAULT_TILES["softmax"])
    assert set(search_space("paged", "pallas")) == \
        set(DEFAULT_TILES["paged"])
    assert search_space("linear", "ref") == {}
    assert search_space("paged", "xla") == {}
    with pytest.raises(KeyError):
        search_space("nope", "pallas")
    with pytest.raises(KeyError):
        default_tiles("nope")


def test_candidates_clamped_deduped_nonempty():
    cands = candidates("linear", "pallas", _shape(n=100))
    chunks = sorted(c["chunk"] for c in cands)
    assert chunks == sorted(set(chunks)), "clamped duplicates must merge"
    assert all(c["chunk"] <= 100 for c in cands)
    # paged: pages_per_block clamps to pmax, not n
    cands = candidates("paged", "pallas", _shape(n=64, page_size=16))
    assert max(c["pages_per_block"] for c in cands) <= 4
    # a tiny VMEM budget still yields the clamped default
    cands = candidates("softmax", "pallas", _shape(n=4096), vmem_budget=1)
    assert len(cands) == 1
    assert vmem_bytes_estimate("softmax", cands[0], _shape(n=4096)) > 1
    assert candidates("linear", "ref", _shape()) == [{}]


# ---------------------------------------------------------------------------
# dispatch integration
# ---------------------------------------------------------------------------

def test_empty_cache_dispatch_byte_identical():
    """Installing an EMPTY cache must not change a single bit of any
    family's output vs no cache at all (the acceptance criterion for
    default fallback)."""
    q, k, v = _qkv()
    ld = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(9),
                                            (1, 2, 100)))
    def run_all():
        return [
            ops.la_causal(q, k, v, 1.0, 1.0, 128, "pallas_interpret"),
            ops.softmax_attention(q, k, v, backend="pallas_interpret"),
            ops.gla_causal(q, k, v, ld, 1.0, 1.0, 64, "pallas_interpret"),
            # ssd: q and k share the group head count
            ops.ssd_causal(k, k, v, ld, 64, "pallas_interpret"),
        ]
    base = run_all()
    tune.activate(TuningCache())          # empty cache installed
    try:
        tuned = run_all()
    finally:
        tune.deactivate()
    for a, b in zip(base, tuned):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_hit_resolves_tuned_chunk(monkeypatch):
    """The pinned acceptance criterion: a cache entry actually changes
    the tile the kernel launches with (spied via la_fwd_pallas), and no
    cache means the caller's chunk flows through untouched."""
    from repro.kernels import linear_attention as kla
    seen = []
    real = kla.la_fwd_pallas

    def spy(q, k, v, a, b, chunk=128, **kw):
        seen.append(chunk)
        return real(q, k, v, a, b, chunk=chunk, **kw)

    monkeypatch.setattr(kla, "la_fwd_pallas", spy)
    q, k, v = _qkv()
    ops.la_causal(q, k, v, 1.0, 1.0, 64, "pallas_interpret")
    assert seen[-1] == 64                 # no cache: caller chunk

    cache = TuningCache()
    cache.put("linear", "pallas_interpret", "fwd", _shape(), jnp.float32,
              {"chunk": 32})
    tune.activate(cache)
    try:
        o_tuned = ops.la_causal(q, k, v, 1.0, 1.0, 64, "pallas_interpret")
        assert seen[-1] == 32             # hit: swept winner wins
    finally:
        tune.deactivate()
    o_default = ops.la_causal(q, k, v, 1.0, 1.0, 64, "pallas_interpret")
    assert seen[-1] == 64
    np.testing.assert_allclose(np.asarray(o_tuned), np.asarray(o_default),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("family", ["linear", "gla", "ssd", "softmax",
                                    "paged"])
def test_tuned_vs_default_parity(family):
    """Tuned tiles are perf knobs: fwd outputs (and grads, for training
    families) match the untuned defaults on every family."""
    q, k, v = _qkv()
    cache = TuningCache()
    if family == "linear":
        fn = lambda q, k, v: ops.la_causal(q, k, v, 1.0, 1.0, 128,
                                           "pallas_interpret")
        args, tiles = (q, k, v), {"chunk": 32}
    elif family == "gla":
        ld = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                                (1, 2, 100)))
        fn = lambda q, k, v, ld: ops.gla_causal(q, k, v, ld, 1.0, 1.0,
                                                128, "pallas_interpret")
        args, tiles = (q, k, v, ld), {"chunk": 32}
    elif family == "ssd":
        # q and k share the group head count (hkv); v/decay carry h
        ld = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3),
                                                (1, 2, 100)))
        fn = lambda k, v, ld: ops.ssd_causal(k, k, v, ld, 128,
                                             "pallas_interpret")
        args, tiles = (k, v, ld), {"chunk": 32}
    elif family == "softmax":
        fn = lambda q, k, v: ops.softmax_attention(
            q, k, v, backend="pallas_interpret")
        args, tiles = (q, k, v), {"block_q": 64, "block_k": 32}
    else:  # paged (inference-only): one-token decode over a page arena
        b, h, hkv, d, ps, pmax = 2, 4, 2, 16, 8, 5
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        qd = jax.random.normal(ks[0], (b, h, 1, d)) * 0.3
        kp = jax.random.normal(ks[1], (b * pmax + 1, hkv, ps, d)) * 0.3
        vp = jax.random.normal(ks[2], (b * pmax + 1, hkv, ps, d))
        pt = jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
        lens = jnp.array([37, 12], jnp.int32)
        fn = lambda qd: ops.paged_attention(qd, kp, vp, pt, lens,
                                            backend="pallas_interpret")
        args, tiles = (qd,), {"pages_per_block": 2}
        cache.put("paged", "pallas_interpret", "fwd",
                  ops._paged_shape(qd, kp, pt), jnp.float32, tiles)

    if family != "paged":
        # ssd keys on the dispatch-derived shape (h from v, hkv from q)
        key_shape = _shape(h=2) if family == "ssd" else _shape()
        for op in ("fwd", "bwd"):
            cache.put(family, "pallas_interpret", op, key_shape,
                      jnp.float32, tiles)

    o_default = fn(*args)
    if family != "paged":
        argnums = tuple(range(len(args)))
        g_default = jax.grad(lambda *a: jnp.sum(fn(*a) ** 2),
                             argnums=argnums)(*args)
    tune.activate(cache)
    try:
        o_tuned = fn(*args)
        if family != "paged":
            g_tuned = jax.grad(lambda *a: jnp.sum(fn(*a) ** 2),
                               argnums=argnums)(*args)
    finally:
        tune.deactivate()
    np.testing.assert_allclose(np.asarray(o_tuned), np.asarray(o_default),
                               rtol=2e-4, atol=2e-4)
    if family != "paged":
        for gt, gd in zip(g_tuned, g_default):
            np.testing.assert_allclose(np.asarray(gt), np.asarray(gd),
                                       rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# sweep CLI end-to-end (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_sweep_cli_populates_cache_and_dispatch_uses_it(tmp_path,
                                                        monkeypatch):
    """`python -m repro.tune sweep --family linear --impl
    pallas_interpret` writes a cache file, and a subsequent kernel call
    resolves its tuned block size from it."""
    from repro.tune.__main__ import main as tune_main
    cache_path = str(tmp_path / "cache.json")
    json_out = str(tmp_path / "BENCH_autotune.json")
    rc = tune_main(["sweep", "--family", "linear", "--impl",
                    "pallas_interpret", "--b", "1", "--h", "2", "--hkv",
                    "2", "--d", "16", "--seq", "64", "--reps", "1",
                    "--cache", cache_path, "--json-out", json_out])
    assert rc == 0

    doc = json.load(open(cache_path))
    assert validate(doc) == []
    assert len(doc["entries"]) == 1
    bench = json.load(open(json_out))
    assert bench["sweeps"][0]["candidates"], "sweep must record candidates"
    for cand in bench["sweeps"][0]["candidates"]:
        assert cand["roofline"]["t_roofline_s"] > 0
        assert "achieved_frac" in cand["roofline"]

    # dispatch resolves the swept winner (spy on the kernel entry)
    from repro.kernels import linear_attention as kla
    seen = []
    real = kla.la_fwd_pallas

    def spy(q, k, v, a, b, chunk=128, **kw):
        seen.append(chunk)
        return real(q, k, v, a, b, chunk=chunk, **kw)

    monkeypatch.setattr(kla, "la_fwd_pallas", spy)
    winner = next(iter(doc["entries"].values()))["tiles"]["chunk"]
    q, k, v = _qkv(b=1, h=2, hkv=2, n=64, d=16)
    tune.activate(cache_path)
    try:
        ops.la_causal(q, k, v, 1.0, 1.0, 512, "pallas_interpret")
    finally:
        tune.deactivate()
    assert seen[-1] == winner

    # bench_check accepts the artifact
    from repro.tune.bench_check import main as check_main
    assert check_main([json_out]) == 0


def test_sweep_fwdbwd_writes_both_ops(tmp_path):
    from repro.tune.sweep import sweep_shape
    cache = TuningCache(path=str(tmp_path / "c.json"))
    record = sweep_shape("gla", "pallas_interpret",
                         _shape(b=1, h=2, hkv=2, n=64, d=16),
                         op="fwdbwd", reps=1, cache=cache,
                         log=lambda *a: None)
    assert record["best"]["tiles"]
    for op in ("fwd", "bwd"):
        hit = cache.lookup("gla", "pallas_interpret", op,
                           _shape(b=1, h=2, hkv=2, n=64, d=16),
                           jnp.float32)
        assert hit == record["best"]["tiles"]


# ---------------------------------------------------------------------------
# timer + roofline helpers
# ---------------------------------------------------------------------------

def test_measure_counts_and_stats():
    calls = []
    m = measure(lambda: calls.append(1), reps=4, warmup=2)
    assert len(calls) == 6                # warmup runs, never timed
    assert m.reps == 4 and m.warmup == 2
    assert m.min_s <= m.median_s <= m.max_s
    with pytest.raises(ValueError):
        measure(lambda: None, reps=0)


def test_kernel_roofline_contract():
    costs = attention_costs("softmax", _shape(n=1024))
    assert costs["flops"] > 0 and costs["bytes"] > 0
    cell = kernel_roofline(costs["flops"], costs["bytes"], time_s=1.0,
                           device="tpu")
    assert cell["t_roofline_s"] > 0
    assert cell["achieved_frac"] == pytest.approx(cell["t_roofline_s"])
    assert cell["bound"] in ("compute", "memory")
    # unmeasured: frac is None but the denominator survives
    cell = kernel_roofline(costs["flops"], costs["bytes"], device="cpu")
    assert cell["achieved_frac"] is None
    assert cell["t_roofline_s"] > 0
    # fwdbwd costs strictly dominate fwd
    fb = attention_costs("linear", _shape(), op="fwdbwd")
    f = attention_costs("linear", _shape(), op="fwd")
    assert fb["flops"] > f["flops"] and fb["bytes"] > f["bytes"]
    with pytest.raises(KeyError):
        attention_costs("nope", _shape())
    with pytest.raises(ValueError):
        attention_costs("linear", _shape(), op="sideways")
