import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _cpu_f32():
    # tests run in f32 on the single CPU device; the 512-device dry-run
    # is exercised via a subprocess (test_dryrun.py)
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
