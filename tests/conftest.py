import jax
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute subprocess tests (dry-run meshes)")


@pytest.fixture(scope="session", autouse=True)
def _cpu_f32():
    # tests run in f32 on the single CPU device; the 512-device dry-run
    # is exercised via a subprocess (test_dryrun.py)
    jax.config.update("jax_enable_x64", False)
    yield


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


# -- consolidated harness (tests/helpers.py) as fixtures ------------------

@pytest.fixture
def backend_cfg():
    """Factory fixture: the tiny shared backend-test ModelConfig."""
    from helpers import backend_cfg as factory
    return factory


@pytest.fixture
def engine_harness():
    """Factory fixture: (cfg, params, base_kw, *variants) -> base run,
    asserting greedy token identity across the engine variants."""
    from helpers import assert_engine_identity
    return assert_engine_identity
