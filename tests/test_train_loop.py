"""Fault-tolerant training loop: convergence, checkpoint/restart after
injected failures, straggler detection, data determinism."""
import os

import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as mdl
from repro.train.loop import StragglerMonitor, Trainer

import jax


def _trainer(tmp_path, steps=6, ckpt_every=2, seed=0):
    cfg = get_config("pythia-1.4b", smoke=True)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=steps,
                     checkpoint_every=ckpt_every,
                     checkpoint_dir=str(tmp_path / "ckpt"))
    params = mdl.init_params(cfg, jax.random.PRNGKey(seed))
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=seed)
    return Trainer(cfg, tc, params, data)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=10)
    hist = tr.run(10)
    assert len(hist) == 10
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    """A step that raises mid-run must roll back to the last checkpoint,
    REPLAY the lost steps from the deterministic data stream, and reach
    the same final trajectory as an uninterrupted run."""
    clean = _trainer(tmp_path / "a")
    clean_hist = clean.run(6)
    clean_by_step = {h["step"]: h["loss"] for h in clean_hist}

    failed = {"done": False}

    def injector(step):
        if step == 4 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("injected node failure")

    tr = _trainer(tmp_path / "b")
    hist = tr.run(6, fail_injector=injector)
    assert failed["done"], "injector never fired"
    # the history contains the replayed steps (roll-back is visible)
    assert len(hist) >= 6
    assert hist[-1]["step"] == 5
    # last execution of every step must match the clean run exactly
    last_by_step = {}
    for h in hist:
        last_by_step[h["step"]] = h["loss"]
    for step, loss in last_by_step.items():
        np.testing.assert_allclose(loss, clean_by_step[step], rtol=1e-5,
                                   err_msg=f"step {step}")


def test_failure_without_checkpoint_retries(tmp_path):
    count = {"n": 0}

    def injector(step):
        if step == 0 and count["n"] < 2:
            count["n"] += 1
            raise RuntimeError("flaky first step")

    tr = _trainer(tmp_path, ckpt_every=0)
    hist = tr.run(3, fail_injector=injector)
    assert count["n"] == 2
    assert len(hist) == 3


def test_persistent_failure_aborts(tmp_path):
    def injector(step):
        raise RuntimeError("dead node")

    tr = _trainer(tmp_path)
    with pytest.raises(RuntimeError, match="dead node"):
        tr.run(3, fail_injector=injector)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    for _ in range(10):
        assert not mon.record(1.0)
    assert mon.record(10.0)          # 10x median
    assert not mon.record(1.1)
    assert mon.flagged == 1


def test_straggler_remesh_signal():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        mon.record(1.0)
    for _ in range(12):
        mon.record(5.0)  # degraded node: first few flagged
    assert mon.needs_remesh


def test_data_determinism():
    a = SyntheticLM(1000, 16, 4, seed=7)
    b = SyntheticLM(1000, 16, 4, seed=7)
    np.testing.assert_array_equal(a.batch_at(5), b.batch_at(5))
    assert not np.array_equal(a.batch_at(5), a.batch_at(6))


def test_memmap_pipeline(tmp_path):
    from repro.data.pipeline import MemmapLM, Prefetcher
    path = os.path.join(tmp_path, "tokens.bin")
    np.arange(10000, dtype=np.int32).tofile(path)
    src = MemmapLM(path, seq_len=16, global_batch=4)
    b0 = src.batch_at(0)
    assert b0.shape == (4, 16)
    np.testing.assert_array_equal(b0.ravel()[:16], np.arange(16))
    pf = Prefetcher(iter([src.batch_at(i) for i in range(3)]))
    got = list(pf)
    assert len(got) == 3
