"""Decay-gated LA ("gla" family) kernel correctness: impl parity (xla
vs pallas-interpret vs the quadratic oracle) for forward AND gradients
— g ∈ {1, 4}, odd N, bf16 —, chunk-size invariance, the decay == 1.0
degeneration to the linear family (the parity anchor), prefill + decode
vs full apply, and the O(N D) residual contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_impl_parity
from repro.core import chunked
from repro.core import gla as cgla
from repro.core.numerics import l2_normalize
from repro.kernels import gla as kgla
from repro.kernels import ops, ref

SHAPES = [
    # (B, H, Hkv, N, D, chunk)
    (1, 1, 1, 8, 4, 4),
    (2, 4, 4, 64, 16, 16),
    (2, 4, 2, 100, 32, 32),      # GQA + ragged N
    (1, 8, 1, 96, 64, 128),      # MQA, chunk > N
    (3, 6, 3, 33, 8, 16),        # odd N
]
DTYPES = [jnp.float32, jnp.bfloat16]
IMPLS = ["xla", "pallas_interpret", "ref"]


def _make(b, h, hkv, n, d, dtype=jnp.float32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    q = l2_normalize(jax.random.normal(ks[0], (b, h, n, d), jnp.float32))
    k = l2_normalize(jax.random.normal(ks[1], (b, hkv, n, d), jnp.float32))
    v = jax.random.normal(ks[2], (b, hkv, n, d), jnp.float32)
    ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, hkv, n)))
    return q.astype(dtype), k.astype(dtype), v.astype(dtype), ld


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fwd_impl_parity(shape, dtype):
    """All registered gla impls agree with the oracle, f32 and bf16."""
    b, h, hkv, n, d, c = shape
    q, k, v, ld = _make(b, h, hkv, n, d, dtype)
    o_ref = ref.gla_ref(q, k, v, ld, 1.0, 1.0)
    assert_impl_parity(
        lambda impl: ops.gla_causal(q, k, v, ld, 1.0, 1.0, c, impl),
        IMPLS, **_tol(dtype), label=f"gla fwd {shape}")
    o = ops.gla_causal(q, k, v, ld, 1.0, 1.0, c, "xla")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("ab", [(1.0, 1.0), (0.5, 2.0)])
def test_general_coeffs(ab):
    """The gate composes with the paper's f(x) = a + b x coefficients."""
    a, b_ = ab
    q, k, v, ld = _make(2, 4, 2, 40, 16)
    o_ref = ref.gla_ref(q, k, v, ld, a, b_)
    o, _, _ = cgla.gla_fwd_chunked(q, k, v, ld, a, b_, chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
    o_pl, _ = kgla.gla_fwd_pallas(q, k, v, ld, a, b_, chunk=16,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("group", [1, 4])
@pytest.mark.parametrize("n", [32, 45])
def test_grad_impl_parity(group, n):
    """Gradients (q, k, v AND log_decay) agree across impls and match
    autodiff of the quadratic oracle — group sizes 1 and 4, odd N."""
    b, h, d, c = 2, 4, 16, 16
    q, k, v, ld = _make(b, h, h // group, n, d)
    w = jax.random.normal(jax.random.PRNGKey(7), (b, h, n, d))

    def grads(impl):
        return jax.grad(lambda q, k, v, ld: jnp.sum(
            ops.gla_causal(q, k, v, ld, 1.0, 1.0, c, impl) * w),
            argnums=(0, 1, 2, 3))(q, k, v, ld)

    assert_impl_parity(grads, ["xla", "pallas_interpret"],
                       rtol=2e-4, atol=2e-4, label=f"gla grads g={group}")
    g_ref = jax.grad(lambda q, k, v, ld: jnp.sum(
        ref.gla_ref(q, k, v, ld, 1.0, 1.0) * w),
        argnums=(0, 1, 2, 3))(q, k, v, ld)
    for name, a_, b_ in zip(("dq", "dk", "dv", "dld"), grads("xla"),
                            g_ref):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"{name} (g={group}, n={n})")


def test_grad_bf16():
    """bf16 inputs train through the gla custom vjp; log_decay (f32)
    keeps an f32 gradient."""
    b, h, hkv, n, d = 2, 4, 2, 40, 16
    q, k, v, ld = _make(b, h, hkv, n, d, jnp.bfloat16)

    def loss(q, k, v, ld, impl):
        return jnp.sum(ops.gla_causal(q, k, v, ld, 1.0, 1.0, 16,
                                      impl).astype(jnp.float32) ** 2)

    g_pl = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, ld,
                                                "pallas_interpret")
    g_x = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, ld, "xla")
    for name, a_, b_ in zip(("dq", "dk", "dv", "dld"), g_pl, g_x):
        assert a_.dtype == b_.dtype, name
        np.testing.assert_allclose(np.asarray(a_, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=2e-2, atol=2e-2, err_msg=name)
    assert g_pl[0].dtype == jnp.bfloat16
    assert g_pl[3].dtype == jnp.float32


@pytest.mark.parametrize("c", [32, 128])
def test_chunk_size_invariance(c):
    """chunk ∈ {32, 128} (and the ragged tail) give identical outputs
    and states — the inter-chunk decay carry is exact."""
    q, k, v, ld = _make(2, 4, 2, 96, 16)
    o_ref, g_ref, st_ref = cgla.gla_fwd_chunked(q, k, v, ld, 1.0, 1.0,
                                                chunk=8)
    o, g, st = cgla.gla_fwd_chunked(q, k, v, ld, 1.0, 1.0, chunk=c)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st.s), np.asarray(st_ref.s),
                               rtol=1e-4, atol=1e-4)
    o_pl, _ = kgla.gla_fwd_pallas(q, k, v, ld, 1.0, 1.0, chunk=c,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_decay_one_degenerates_to_linear_family():
    """log_decay == 0 (gamma == 1) is EXACTLY the linear family: same
    outputs, same normalizer, same state, same gradients."""
    b, h, hkv, n, d, c = 2, 4, 2, 64, 16, 16
    q, k, v, _ = _make(b, h, hkv, n, d)
    z = jnp.zeros((b, hkv, n))
    o_g, g_g, st_g = cgla.gla_fwd_chunked(q, k, v, z, 1.0, 1.0, chunk=c)
    o_l, g_l, st_l = chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, chunk=c)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_l),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_g), np.asarray(g_l),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_g.s), np.asarray(st_l.s),
                               rtol=1e-5, atol=1e-5)

    w = jax.random.normal(jax.random.PRNGKey(3), o_g.shape)
    g1 = jax.grad(lambda q, k, v: jnp.sum(
        ops.gla_causal(q, k, v, z, 1.0, 1.0, c, "xla") * w),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(
        ops.la_causal(q, k, v, 1.0, 1.0, c, "xla") * w),
        argnums=(0, 1, 2))(q, k, v)
    for name, a_, b_ in zip(("dq", "dk", "dv"), g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_prefill_decode_chain_matches_full():
    """gla_prefill + gla_decode_step == the full chunked forward, with
    the decayed state carried across the split."""
    b, h, hkv, n, d = 2, 4, 2, 40, 16
    q, k, v, ld = _make(b, h, hkv, n, d)
    o_full, _, _ = cgla.gla_fwd_chunked(q, k, v, ld, 1.0, 1.0, chunk=16)
    o_pre, st = ops.gla_prefill(q[:, :, :30], k[:, :, :30], v[:, :, :30],
                                ld[:, :, :30], 1.0, 1.0, 16)
    np.testing.assert_allclose(np.asarray(o_pre),
                               np.asarray(o_full[:, :, :30]),
                               rtol=1e-5, atol=1e-5)
    for i in range(30, n):
        st, o_i = ops.gla_decode_step(st, q[:, :, i], k[:, :, i],
                                      v[:, :, i], ld[:, :, i], 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(o_i),
                                   np.asarray(o_full[:, :, i]),
                                   rtol=1e-4, atol=1e-4)


def test_continuation_prefill_from_state():
    """Windowed prefill (state carried between windows) == one-shot."""
    b, h, hkv, n, d = 2, 4, 2, 24, 8
    q, k, v, ld = _make(b, h, hkv, n, d, key=1)
    o_full, _, st_full = cgla.gla_fwd_chunked(q, k, v, ld, 1.0, 1.0,
                                              chunk=8)
    st, outs = None, []
    for s in range(0, n, 10):
        e = min(s + 10, n)
        o_w, st = ops.gla_prefill(q[:, :, s:e], k[:, :, s:e],
                                  v[:, :, s:e], ld[:, :, s:e],
                                  1.0, 1.0, 8, state=st)
        outs.append(o_w)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 2)),
                               np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.s), np.asarray(st_full.s),
                               rtol=1e-4, atol=1e-4)


def test_residual_memory_is_linear():
    """The custom vjp must store only {q, k, v, ld, o, g} — O(N D)."""
    b, h, n, d = 1, 2, 64, 16
    q, k, v, ld = _make(b, h, h, n, d)
    _, vjp = jax.vjp(lambda *a: ops.gla_causal(*a, 1.0, 1.0, 16, "xla"),
                     q, k, v, ld)
    res_elems = sum(x.size for x in jax.tree.leaves(vjp)
                    if hasattr(x, "size"))
    # q,k,v,o: 4*(B*H*N*D); g + ld: 2 * B*H*N  (plus small constants)
    budget = 4 * b * h * n * d + 2 * b * h * n
    assert res_elems <= budget * 1.5, (res_elems, budget)


def test_state_size_independent_of_context():
    """The gated deployment story matches the paper's: O(D^2) state."""
    st = cgla.init_gla_state(2, 4, 64)
    assert st.s.shape == (2, 4, 64, 65)
    assert st.p.shape == (2, 4, 65)


@pytest.mark.parametrize("n", [40, 100])
def test_padded_rows_no_nan_with_zero_a(n):
    """Regression: N not a multiple of chunk pads rows whose normalizer
    is 0 when a == 0 — the guarded finalize must keep the kernel
    NaN-free under jax_debug_nans (the flash kernel's PR 3 contract,
    held by the gated kernel too) and the real rows exact."""
    b, h, hkv, d = 1, 2, 2, 8
    q, k, v, ld = _make(b, h, hkv, n, d)
    # a == 0 drops the constant term, so REAL rows keep g > 0 only if
    # the scores do — use elementwise-positive q/k (feature-mapped
    # kernels are positive; this probes the padded rows, not sign math)
    q, k = jnp.abs(q), jnp.abs(k)
    jax.config.update("jax_debug_nans", True)
    try:
        o, g = kgla.gla_fwd_pallas(q, k, v, ld, 0.0, 1.0, chunk=16,
                                   interpret=True)
    finally:
        jax.config.update("jax_debug_nans", False)
    assert np.isfinite(np.asarray(o)).all()
    np.testing.assert_allclose(np.asarray(o),
                               np.asarray(ref.gla_ref(q, k, v, ld,
                                                      0.0, 1.0)),
                               rtol=2e-4, atol=2e-4)


def test_strong_decay_is_stable():
    """Hard gating (gamma ~ 1e-4 per token) must stay finite — the
    masked decay exponents are clamped before exp in every impl."""
    b, h, hkv, n, d = 1, 2, 2, 64, 8
    q, k, v, _ = _make(b, h, hkv, n, d)
    ld = jnp.full((b, hkv, n), -9.0)
    for impl in IMPLS:
        o = ops.gla_causal(q, k, v, ld, 1.0, 1.0, 16, impl)
        assert np.isfinite(np.asarray(o)).all(), impl
    w = jnp.ones((b, h, n, d))
    g = jax.grad(lambda q, k, v, ld: jnp.sum(
        ops.gla_causal(q, k, v, ld, 1.0, 1.0, 16, "xla") * w),
        argnums=(0, 1, 2, 3))(q, k, v, ld)
    for g_ in g:
        assert np.isfinite(np.asarray(g_)).all()
