"""AdamW + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, schedules


def test_adamw_first_step_is_lr_signed():
    """With fresh moments, AdamW's first update is lr * sign-ish(g)."""
    p = {"w": jnp.zeros((4, 4))}
    g = {"w": jnp.full((4, 4), 2.0)}
    st = adamw.init(p)
    p2, st2, m = adamw.apply(p, g, st, lr=0.1, weight_decay=0.0,
                             grad_clip=0.0)
    np.testing.assert_allclose(np.asarray(p2["w"]), -0.1, rtol=1e-4)
    assert int(st2.step) == 1


def test_weight_decay_only_on_matrices():
    p = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    g = {"w": jnp.zeros((4, 4)), "scale": jnp.zeros((4,))}
    st = adamw.init(p)
    p2, _, _ = adamw.apply(p, g, st, lr=0.1, weight_decay=0.1,
                           grad_clip=0.0)
    assert float(p2["w"][0, 0]) < 1.0       # decayed
    assert float(p2["scale"][0]) == 1.0     # not decayed


def test_grad_clip():
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.full((3,), 100.0)}
    st = adamw.init(p)
    _, _, m = adamw.apply(p, g, st, lr=0.1, grad_clip=1.0)
    assert float(m["grad_norm"]) > 1.0  # reports the raw norm


def test_moments_in_f32_for_bf16_params():
    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw.init(p)
    assert st.mu["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    p2, st2, _ = adamw.apply(p, g, st, lr=0.1)
    assert p2["w"].dtype == jnp.bfloat16
    assert st2.nu["w"].dtype == jnp.float32


def test_cosine_schedule_endpoints():
    """Paper §5.2: warmup to max 1e-3, cosine decay to min 5e-5."""
    kw = dict(max_lr=1e-3, min_lr=5e-5, warmup_steps=100, total_steps=1000)
    assert float(schedules.cosine_warmup_decay(0, **kw)) == 0.0
    np.testing.assert_allclose(
        float(schedules.cosine_warmup_decay(100, **kw)), 1e-3, rtol=1e-3)
    np.testing.assert_allclose(
        float(schedules.cosine_warmup_decay(1000, **kw)), 5e-5, rtol=1e-3)
    mid = float(schedules.cosine_warmup_decay(550, **kw))
    assert 5e-5 < mid < 1e-3


def test_schedule_monotone_after_warmup():
    kw = dict(max_lr=1e-3, min_lr=5e-5, warmup_steps=10, total_steps=100)
    lrs = [float(schedules.cosine_warmup_decay(s, **kw))
           for s in range(10, 101, 5)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))
