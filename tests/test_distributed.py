"""Distribution substrate: sharding rules, ZeRO specs, gradient
compression, elastic re-meshing (single-device where possible; the
512-device production meshes are exercised by test_dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from helpers import HAS_AXIS_TYPE

if not HAS_AXIS_TYPE:
    pytest.skip("jax.sharding.AxisType unavailable on this jax version "
                "(launch/elastic.py imports it at module scope)",
                allow_module_level=True)

from repro.configs.registry import ARCHS, get_config  # noqa: E402
from repro.distributed import compression  # noqa: E402
from repro.distributed.sharding import batch_spec, param_spec  # noqa: E402
from repro.distributed.zero import moment_spec  # noqa: E402
from repro.launch import elastic  # noqa: E402


class FakeMesh:
    """Shape-only stand-in so spec rules can be tested without devices."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def test_param_spec_2d_weight():
    spec = param_spec("blocks/mixer/wq/w", (36, 2048, 2048), MESH)
    assert spec[0] is None                      # layer-stacked dim
    assert set(spec[1:]) == {"data", "model"}


def test_param_spec_expert_weights():
    spec = param_spec("blocks/ffn/experts/wi", (60, 160, 5120, 1536), MESH)
    assert spec[0] is None
    assert spec[1] == "model"                   # EP: experts over model
    assert "data" in spec[2:]                   # FSDP on a big dim


def test_param_spec_non_divisible_falls_back():
    spec = param_spec("embed/table", (51866, 1280), MESH)  # whisper vocab
    assert "model" in spec or "data" in spec    # d=1280 shardable
    assert spec[0] is None                      # 51866 % 16 != 0


def test_param_spec_1d_replicated():
    assert param_spec("ln_f/scale", (2048,), MESH) == P()


def test_every_arch_param_tree_has_valid_specs(rng):
    """Every param of every (reduced) arch gets a spec whose sharded dims
    divide; and the same rules applied to FULL shapes never fail."""
    from functools import partial
    from repro.models import model as mdl
    for arch in ARCHS:
        for smoke in (True, False):
            cfg = get_config(arch, smoke=smoke)
            shapes = jax.eval_shape(partial(mdl.init_params, cfg),
                                    jax.ShapeDtypeStruct((2,), jnp.uint32))

            def check(path, leaf):
                pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                                for k in path)
                spec = param_spec(pstr, leaf.shape, MESH)
                for i, ax in enumerate(spec):
                    if ax is None:
                        continue
                    size = {"data": 16, "model": 16}[ax]
                    assert leaf.shape[i] % size == 0, \
                        f"{arch} {pstr} {leaf.shape} {spec}"
            jax.tree_util.tree_map_with_path(check, shapes)


def test_batch_spec_divisibility():
    assert batch_spec((256, 4096), MESH)[0] == "data"
    assert batch_spec((256, 4096), MESH3)[0] == ("pod", "data")
    assert batch_spec((1, 524288), MESH3)[0] is None          # long_500k
    assert batch_spec((8, 128), MESH3)[0] in ("pod", ("pod",))  # partial


def test_moment_spec_adds_zero1_sharding():
    # a weight that could not be data-sharded gets its moments sharded
    spec = moment_spec("x/w", (48, 2048), FakeMesh(data=16, model=16))
    assert "data" in spec or "model" in spec


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 10
    y = compression.compress_decompress(x)
    err = float(jnp.abs(x - y).max())
    assert err <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_error_feedback_removes_bias():
    """With error feedback the time-averaged compressed gradient must
    converge to the true gradient (the residual is carried, not lost)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    steps = 200
    for _ in range(steps):
        gf = g + err
        q, s = compression.quantize_int8(gf)
        sent = compression.dequantize_int8(q, s)
        err = gf - sent
        acc = acc + sent
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g),
                               atol=0.02)


def test_compressed_psum_single_axis():
    """shard_map over the host's single device (axis size 1): the psum
    plumbing works and returns the (averaged) gradient."""
    from jax.sharding import NamedSharding
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.arange(8, dtype=jnp.float32)}
    e = {"w": jnp.zeros(8)}

    def f(g, e):
        return compression.compressed_psum(g, e, "data")

    out, new_e = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()))(g, e)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(g["w"]), atol=0.05)


def test_elastic_mesh_shrink():
    assert elastic.choose_mesh_shape(256, 16) == (16, 16)
    assert elastic.choose_mesh_shape(240, 16) == (8, 16)   # lost a host
    assert elastic.choose_mesh_shape(128, 16) == (8, 16)
    with pytest.raises(RuntimeError):
        elastic.choose_mesh_shape(8, 16)


def test_elastic_batch_rescale():
    old = FakeMesh(pod=2, data=16, model=16)
    new = FakeMesh(pod=2, data=8, model=16)
    assert elastic.rescale_batch(256, old, new) == 128
