"""Attention-backend registry: resolution errors, kernel-impl parity
(xla vs pallas_interpret vs ref) at the backend level, prefill+decode vs
full-sequence apply, GQA noncausal paths, and the per-slot softmax
decode-position regression (continuous batching)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_impl_parity, backend_cfg as _cfg, with_impl
from repro.configs.base import LACfg
from repro.kernels import ops
from repro.mixers import get_backend, get_mixer, registered_backends

B, N, D_MODEL = 2, 24, 32   # head counts live in helpers.backend_cfg

# the suite predates tests/helpers.py; keep its local alias
_with_impl = with_impl


def _x(key, n=N):
    return jax.random.normal(key, (B, n, D_MODEL)) * 0.2


def _positions(n=N):
    return jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (B, n))


# ---------------------------------------------------------------------------
# Registry resolution + validation
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"linear", "gla", "softmax", "mla", "mamba2"} <= set(
        registered_backends())
    assert get_mixer is get_backend


def test_mixer_resolution():
    assert get_backend(_cfg()).name == "linear"
    assert get_backend(_cfg(attention_backend="softmax")).name == "softmax"
    assert get_backend(_cfg(mixer="mamba2")).name == "mamba2"
    # non-attention mixers resolve by mixer name, not attention_backend
    assert get_backend(_cfg(mixer="mamba2",
                            attention_backend="softmax")).name == "mamba2"


def test_unknown_backend_lists_registered_names():
    with pytest.raises(KeyError) as exc:
        get_backend(_cfg(attention_backend="performer"))
    msg = str(exc.value)
    assert "performer" in msg
    for name in registered_backends():
        assert name in msg


def test_unknown_kernel_impl_lists_registered_names():
    with pytest.raises(ValueError) as exc:
        get_backend(_with_impl(_cfg(), "cuda"))
    msg = str(exc.value)
    assert "cuda" in msg and "xla" in msg and "pallas" in msg


def test_nonpositive_chunk_rejected():
    with pytest.raises(ValueError, match="chunk"):
        get_backend(dataclasses.replace(_cfg(), la=LACfg(chunk=0)))


def test_encdec_requires_cross_capability():
    """A softmax whisper config must fail at resolution, not deep inside
    a jitted prefill (the softmax backend has no cross-decode path)."""
    cfg = _cfg(family="encdec", attention_backend="softmax",
               encoder_layers=2, encoder_seq=8)
    with pytest.raises(ValueError, match="cross"):
        get_backend(cfg)
    assert get_backend(dataclasses.replace(
        cfg, attention_backend="linear")).name == "linear"


def test_kernel_registry_families():
    for family in ("linear", "softmax", "ssd", "gla"):
        names = ops.kernel_names(family)
        assert {"xla", "pallas", "pallas_interpret", "ref"} <= set(names)
    with pytest.raises(ValueError, match="registered"):
        ops.get_kernel("linear", "nope")


def test_mamba2_validates_against_ssd_family(rng):
    """cfg.la.backend on a mamba2 config resolves in the "ssd" kernel
    family (ROADMAP: no more internal dispatch in core/ssd)."""
    from repro.configs.base import SSMCfg
    cfg = _cfg(mixer="mamba2", ssm=SSMCfg(state_dim=8, head_dim=8))
    for impl in ("xla", "pallas_interpret", "ref"):
        assert get_backend(_with_impl(cfg, impl)).name == "mamba2"
    with pytest.raises(ValueError) as exc:
        get_backend(_with_impl(cfg, "cuda"))
    assert "ssd" in str(exc.value)


def test_ssd_impl_parity_through_backend(rng):
    """All registered ssd impls agree on the mamba2 backend's apply()
    (grouped q/k included: the ref oracle expands the shared heads)."""
    from repro.configs.base import SSMCfg
    cfg = _cfg(mixer="mamba2", ssm=SSMCfg(state_dim=8, head_dim=8,
                                          expand=2))
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x = _x(jax.random.fold_in(rng, 9))
    outs = {impl: be.apply(p, _with_impl(cfg, impl), x, _positions())
            for impl in ("xla", "pallas_interpret", "ref")}
    for impl in ("pallas_interpret", "ref"):
        np.testing.assert_allclose(
            np.asarray(outs[impl]), np.asarray(outs["xla"]),
            rtol=2e-4, atol=2e-4, err_msg=f"ssd {impl} != xla")


# ---------------------------------------------------------------------------
# Kernel-impl parity through the backend interface
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend_name,impls", [
    ("linear", ["xla", "pallas_interpret", "ref"]),
    ("gla", ["xla", "pallas_interpret", "ref"]),
    ("softmax", ["xla", "pallas_interpret", "ref"]),
])
def test_impl_parity_forward(backend_name, impls, rng):
    """All registered impls of a score family agree on apply()."""
    cfg = _cfg(attention_backend=backend_name)
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 1)), _positions()
    assert_impl_parity(
        lambda impl: be.apply(p, _with_impl(cfg, impl), x, pos),
        impls, rtol=2e-4, atol=2e-4, label=backend_name)


@pytest.mark.parametrize("backend_name",
                         ["linear", "gla", "softmax", "mla", "mamba2"])
def test_prefill_decode_matches_apply(backend_name, rng):
    """prefill(prompt) + decode x k == apply over the full sequence,
    at PER-SLOT decode positions, for every registered mixer."""
    kw = {}
    if backend_name in ("linear", "gla", "softmax"):
        kw["attention_backend"] = backend_name
    elif backend_name == "mla":
        from repro.configs.base import MLACfg
        kw.update(mixer="mla",
                  mla=MLACfg(kv_lora_rank=16, q_lora_rank=16,
                             rope_head_dim=4, nope_head_dim=8,
                             v_head_dim=8))
    else:
        from repro.configs.base import SSMCfg
        kw.update(mixer="mamba2",
                  ssm=SSMCfg(state_dim=8, head_dim=8, expand=2))
    cfg = _cfg(**kw)
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 2)), _positions()
    full = be.apply(p, cfg, x, pos)

    split = N - 4
    cache = be.init_cache(cfg, B, N + 8, jnp.float32)
    y, cache = be.prefill(p, cfg, x[:, :split], pos[:, :split], cache)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, :split]),
                               rtol=2e-4, atol=2e-4)
    for i in range(split, N):
        y, cache = be.decode(p, cfg, x[:, i:i + 1], pos[:, i:i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, i]),
            rtol=1e-3, atol=1e-3, err_msg=f"{backend_name}: token {i}")


@pytest.mark.parametrize("backend_name", ["linear", "softmax"])
def test_noncausal_gqa_matches_oracle(backend_name, rng):
    """apply_noncausal (GQA: 4 query / 2 KV heads) against the quadratic
    oracles, both self-bidirectional and cross-shaped ctx."""
    from repro.kernels import ref
    cfg = _cfg(attention_backend=backend_name, rope_kind="none")
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x = _x(jax.random.fold_in(rng, 3))
    ctx = _x(jax.random.fold_in(rng, 4), n=N + 7)

    from repro.core.numerics import l2_normalize
    from repro.mixers.qkv import merge_heads
    from repro.models.common import dense
    q, k, v = be.project_noncausal(p, cfg, x, ctx, None, None)
    if backend_name == "linear":
        o_ref = ref.la_ref(l2_normalize(q), l2_normalize(k), v, causal=False)
    else:
        o_ref = ref.softmax_ref(q, k, v, causal=False)
    want = dense(p["wo"], merge_heads(o_ref), None)
    got = be.apply_noncausal(p, cfg, x, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_learnable_coeffs_through_backend(rng):
    """cfg.la.learnable_coeffs routes through the same interface: params
    gain (a, b) scalars, output matches fixed coefficients at init, and
    gradients reach the coefficients (paper §2.2)."""
    cfg = _cfg()
    lcfg = dataclasses.replace(cfg, la=dataclasses.replace(
        cfg.la, learnable_coeffs=True))
    be = get_backend(lcfg)
    p = be.init(rng, lcfg, jnp.float32)
    assert "la_a" in p and "la_b" in p
    x, pos = _x(jax.random.fold_in(rng, 5)), _positions()
    fixed = be.apply({k: v for k, v in p.items()
                      if k not in ("la_a", "la_b")}, cfg, x, pos)
    learn = be.apply(p, lcfg, x, pos)
    np.testing.assert_allclose(np.asarray(learn), np.asarray(fixed),
                               rtol=1e-5, atol=1e-5)
    g = jax.grad(lambda p_: jnp.sum(be.apply(p_, lcfg, x, pos) ** 2))(p)
    assert float(jnp.abs(g["la_a"])) > 0


@pytest.mark.parametrize("backend_name,window",
                         [("linear", 6), ("gla", 6), ("softmax", 6),
                          ("mla", 6), ("mamba2", 6), ("mamba2", 2),
                          ("softmax", 2)])
def test_windowed_prefill_matches_oneshot(backend_name, window, rng):
    """Feeding the prompt window-by-window through prefill must match
    one-shot prefill for every backend — softmax via continuation
    prefill (each window attends to the cached prefix), mamba2 even for
    windows shorter than its conv width; gla carries its decayed
    state."""
    kw = {}
    if backend_name in ("linear", "gla", "softmax"):
        kw["attention_backend"] = backend_name
    elif backend_name == "mla":
        from repro.configs.base import MLACfg
        kw.update(mixer="mla",
                  mla=MLACfg(kv_lora_rank=16, q_lora_rank=16,
                             rope_head_dim=4, nope_head_dim=8,
                             v_head_dim=8))
    else:
        from repro.configs.base import SSMCfg
        kw.update(mixer="mamba2",
                  ssm=SSMCfg(state_dim=8, head_dim=8, expand=2))
    cfg = _cfg(**kw)
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 8)), _positions()

    one = be.init_cache(cfg, B, N + 8, jnp.float32)
    y_one, one = be.prefill(p, cfg, x, pos, one)

    chunked = be.init_cache(cfg, B, N + 8, jnp.float32)
    ys = []
    for s in range(0, N, window):
        e = min(s + window, N)
        y_w, chunked = be.prefill(p, cfg, x[:, s:e], pos[:, s:e], chunked)
        ys.append(y_w)
    y_chunked = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_one),
                               rtol=2e-4, atol=2e-4)
    for a, b_ in zip(jax.tree.leaves(one), jax.tree.leaves(chunked)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-3, atol=1e-3)


def test_gla_validates_against_gla_family(rng):
    """cfg.la.backend on a gla config resolves in the "gla" kernel
    family; bad impl names say so."""
    cfg = _cfg("gla")
    for impl in ("xla", "pallas_interpret", "ref"):
        assert get_backend(_with_impl(cfg, impl)).name == "gla"
    with pytest.raises(ValueError) as exc:
        get_backend(_with_impl(cfg, "cuda"))
    assert "gla" in str(exc.value)


def test_gla_paging_validation(backend_cfg):
    """cfg.paging is legal on gla (paged recurrent state) and softmax
    (paged KV) but still rejected everywhere else (uses the conftest
    backend_cfg factory fixture — same object as helpers.backend_cfg)."""
    from repro.configs.base import PagingCfg
    pg = PagingCfg(page_size=8, num_pages=4)
    assert get_backend(backend_cfg("gla", paging=pg)).name == "gla"
    assert get_backend(backend_cfg("softmax", paging=pg)).name == "softmax"
    with pytest.raises(ValueError, match="paging"):
        get_backend(backend_cfg("linear", paging=pg))


def test_gla_pallas_trains_like_xla(rng):
    """gla x pallas_interpret differentiates through the gated custom
    vjp — parameter gradients (decay-gate projection included) match
    the XLA scan (GQA config: 4 query / 2 KV heads)."""
    cfg = _cfg("gla")
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 14)), _positions()

    def loss(p_, impl):
        y = be.apply(p_, _with_impl(cfg, impl), x, pos)
        return jnp.sum(y ** 2)

    g_x = jax.grad(loss)(p, "xla")
    g_pl = jax.grad(loss)(p, "pallas_interpret")
    assert float(jnp.abs(jax.tree.leaves(g_x["wg"])[0]).max()) > 0, \
        "decay gate got no gradient"
    for key in g_x:
        for a, b_ in zip(jax.tree.leaves(g_pl[key]),
                         jax.tree.leaves(g_x[key])):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4,
                err_msg=f"grad[{key}]")


def test_gla_has_no_noncausal_path(rng):
    """Decay gating is causal-only: the encoder/cross capability is
    off, so an encdec config fails at resolution."""
    cfg = _cfg("gla", family="encdec", encoder_layers=2, encoder_seq=8)
    with pytest.raises(ValueError, match="cross"):
        get_backend(cfg)


def test_softmax_pallas_trains_like_xla(rng):
    """flash v2: softmax x pallas_interpret differentiates through the
    registered custom vjp — parameter gradients match the autodiff'd
    XLA scan (GQA config: 4 query / 2 KV heads)."""
    cfg = _cfg(attention_backend="softmax")
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 11)), _positions()

    def loss(p_, impl):
        y = be.apply(p_, _with_impl(cfg, impl), x, pos)
        return jnp.sum(y ** 2)

    g_x = jax.grad(loss)(p, "xla")
    g_pl = jax.grad(loss)(p, "pallas_interpret")
    for key in g_x:
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(g_pl[key])[0]),
            np.asarray(jax.tree.leaves(g_x[key])[0]),
            rtol=2e-4, atol=2e-4, err_msg=f"grad[{key}]")


def test_softmax_continuation_prefill_through_flash(rng):
    """Windowed prefill on the pallas_interpret impl (q_offset through
    the flash kernel's scalar-prefetch path, NOT the XLA fallback) must
    match one-shot prefill on the xla impl."""
    cfg = _cfg(attention_backend="softmax")
    cfg_fl = _with_impl(cfg, "pallas_interpret")
    be = get_backend(cfg_fl)
    p = be.init(rng, cfg, jnp.float32)
    x, pos = _x(jax.random.fold_in(rng, 12)), _positions()

    one = be.init_cache(cfg, B, N + 8, jnp.float32)
    y_one, one = be.prefill(p, cfg, x, pos, one)

    chunked = be.init_cache(cfg_fl, B, N + 8, jnp.float32)
    ys = []
    for s in range(0, N, 6):
        e = min(s + 6, N)
        y_w, chunked = be.prefill(p, cfg_fl, x[:, s:e], pos[:, s:e],
                                  chunked)
        ys.append(y_w)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, axis=1)),
                               np.asarray(y_one), rtol=2e-4, atol=2e-4)
    for a, b_ in zip(jax.tree.leaves(one), jax.tree.leaves(chunked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-3, atol=1e-3)


def test_softmax_continuation_prefill_per_slot_offsets(rng):
    """Two slots whose windows sit at DIFFERENT absolute offsets must
    each attend to exactly their own cached prefix (per-slot q_offset)."""
    cfg = _cfg(attention_backend="softmax")
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    n_a, n_b, w = 12, 5, 6
    xs = _x(jax.random.fold_in(rng, 7), n=n_a + w)

    def alone(n_ctx):
        cache = be.init_cache(cfg, B, 32, jnp.float32)
        _, cache = be.prefill(p, cfg, xs[:, :n_ctx], _positions(n_ctx),
                              cache)
        pos = (jnp.arange(n_ctx, n_ctx + w, dtype=jnp.int32)[None]
               + jnp.zeros((B, 1), jnp.int32))
        y, _ = be.prefill(p, cfg, xs[:, n_ctx:n_ctx + w], pos, cache)
        return y

    y_a, y_b = alone(n_a), alone(n_b)

    cache_a = be.init_cache(cfg, B, 32, jnp.float32)
    _, cache_a = be.prefill(p, cfg, xs[:, :n_a], _positions(n_a), cache_a)
    cache_b = be.init_cache(cfg, B, 32, jnp.float32)
    _, cache_b = be.prefill(p, cfg, xs[:, :n_b], _positions(n_b), cache_b)
    mixed = jax.tree.map(lambda a, b_: jnp.stack([a[0], b_[1]]),
                         cache_a, cache_b)
    x_w = jnp.stack([xs[0, n_a:n_a + w], xs[1, n_b:n_b + w]])
    pos = jnp.stack([jnp.arange(n_a, n_a + w), jnp.arange(n_b, n_b + w)]
                    ).astype(jnp.int32)
    y, _ = be.prefill(p, cfg, x_w, pos, mixed)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_a[0]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(y_b[1]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Per-slot decode positions (continuous batching regression)
# ---------------------------------------------------------------------------

def test_softmax_decode_per_slot_positions(rng):
    """Two slots at DIFFERENT depths must decode exactly like each slot
    would alone (the old code read position[0, 0] for the whole batch)."""
    cfg = _cfg(attention_backend="softmax")
    be = get_backend(cfg)
    p = be.init(rng, cfg, jnp.float32)
    n_a, n_b = 13, 6  # slot depths BEFORE the new token
    xs = _x(jax.random.fold_in(rng, 6), n=n_a + 1)

    def run_alone(n_ctx):
        """Prefill n_ctx tokens, then decode token n_ctx."""
        cache = be.init_cache(cfg, B, 32, jnp.float32)
        pos = _positions(n_ctx)
        _, cache = be.prefill(p, cfg, xs[:, :n_ctx], pos, cache)
        y, _ = be.decode(p, cfg, xs[:, n_ctx:n_ctx + 1],
                         jnp.full((B, 1), n_ctx, jnp.int32), cache)
        return y

    alone_a = run_alone(n_a)
    alone_b = run_alone(n_b)

    # batched: slot 0 at depth n_a, slot 1 at depth n_b, one shared cache
    cache = be.init_cache(cfg, B, 32, jnp.float32)
    _, cache_a = be.prefill(p, cfg, xs[:, :n_a], _positions(n_a),
                            be.init_cache(cfg, B, 32, jnp.float32))
    _, cache_b = be.prefill(p, cfg, xs[:, :n_b], _positions(n_b),
                            be.init_cache(cfg, B, 32, jnp.float32))
    mixed = jax.tree.map(
        lambda a, b_: jnp.stack([a[0], b_[1]]), cache_a, cache_b)
    x_new = jnp.stack([xs[0, n_a], xs[1, n_b]])[:, None]
    position = jnp.asarray([[n_a], [n_b]], jnp.int32)
    y, _ = be.decode(p, cfg, x_new, position, mixed)

    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(alone_a[0]),
                               rtol=1e-4, atol=1e-4,
                               err_msg="deep slot depends on shallow slot")
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(alone_b[1]),
                               rtol=1e-4, atol=1e-4,
                               err_msg="shallow slot read the deep slot's "
                                       "position (old pos = position[0,0])")
