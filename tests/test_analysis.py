"""HLO structural cost analysis: trip-count-corrected flops must match
analytic counts on a known program (the thing XLA's own cost_analysis
gets wrong for loops)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import count_ops, top_dot_sites, total_costs
from repro.analysis.roofline import Roofline


def test_scan_flops_counted_with_trip_count():
    """L iterations of an (n,n)@(n,n) matmul = L * 2n^3 flops."""
    n, L = 64, 7

    def f(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.ones((n, n))
    ws = jnp.ones((L, n, n))
    compiled = jax.jit(f).lower(x, ws).compile()
    costs = total_costs(compiled.as_text())
    expected = L * 2 * n ** 3
    np.testing.assert_allclose(costs["flops"], expected, rtol=0.01)
    # XLA's own analysis undercounts (body once) — the reason we parse.
    # cost_analysis() returned a one-element list of dicts on older jax
    # (0.4.x) and a plain dict on newer; normalize before reading
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    raw = ca.get("flops", 0)
    assert raw < expected / 2


def test_nested_scan_flops():
    n, L1, L2 = 32, 3, 5

    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.dot(c2, w), None
            c, _ = jax.lax.scan(inner, c, None, length=L2)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    compiled = jax.jit(f).lower(jnp.ones((n, n)),
                                jnp.ones((L1, n, n))).compile()
    costs = total_costs(compiled.as_text())
    np.testing.assert_allclose(costs["flops"], L1 * L2 * 2 * n ** 3,
                               rtol=0.01)


def test_plain_matmul_flops():
    m, k, n = 32, 48, 64
    compiled = jax.jit(jnp.dot).lower(jnp.ones((m, k)),
                                      jnp.ones((k, n))).compile()
    costs = total_costs(compiled.as_text())
    np.testing.assert_allclose(costs["flops"], 2 * m * k * n, rtol=0.01)
    assert costs["bytes"] >= 4 * (m * k + k * n + m * n)


def test_top_dot_sites_ranked():
    def f(x, w_small, w_big):
        return jnp.dot(jnp.dot(x, w_small), w_big)

    compiled = jax.jit(f).lower(
        jnp.ones((8, 16)), jnp.ones((16, 16)), jnp.ones((16, 256))).compile()
    sites = top_dot_sites(compiled.as_text(), k=2)
    assert len(sites) == 2
    assert sites[0][0] >= sites[1][0]


def test_count_ops():
    compiled = jax.jit(lambda x: jnp.dot(x, x)).lower(
        jnp.ones((8, 8))).compile()
    assert count_ops(compiled.as_text(), "dot") >= 1


def test_roofline_terms():
    r = Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                 flops_per_device=197e12, bytes_per_device=819e9,
                 collective_bytes=50e9,
                 model_flops=197e12 * 256).finalize()
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 1.0)
    np.testing.assert_allclose(r.t_collective, 1.0)
    np.testing.assert_allclose(r.usefulness, 1.0)
    assert r.roofline_fraction == 1.0


def test_roofline_dominant_detection():
    r = Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                 flops_per_device=1e12, bytes_per_device=819e9 * 2,
                 collective_bytes=1e9, model_flops=1e12).finalize()
    assert r.dominant == "memory"
    assert r.roofline_fraction < 0.01
