"""Fused single-kernel decode step (kernels/decode_fused.py).

Kernel-level: every *_decode_fused registry family must match its
unfused composition bitwise-closely across impls (xla /
pallas_interpret), GQA groupings g ∈ {1, 4}, dtypes (f32 / bf16), both
cache layouts (contiguous and paged), ragged lengths, and non-dividing
tile choices.  Engine-level: greedy decode must be token-identical with
fused_decode on vs off, the jitted decode step must donate its cache
buffers (analysis.hlo.assert_cache_donation), and the all-greedy
sampling fast path must neither consume PRNG keys nor change tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import assert_engine_identity, backend_cfg
from repro.kernels import ops

F32 = jnp.float32
IMPLS = ["xla", "pallas_interpret"]


def _rand(key, shape, dtype=F32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def _warm_state(b, hkv, d, gated=False, steps=3):
    """A populated recurrent state: run a few unfused steps so the fused
    step is tested against non-trivial s/p, not zeros."""
    st = (ops.init_gla_state if gated else ops.init_state)(b, hkv, d, d)
    for i in range(steps):
        k = _rand(10 + i, (b, hkv, d)) * 0.3
        v = _rand(20 + i, (b, hkv, d))
        q = _rand(30 + i, (b, hkv, d)) * 0.3
        if gated:
            ld = -jnp.abs(_rand(40 + i, (b, hkv))) * 0.1
            st, _ = ops.gla_decode_step(st, q, k, v, ld, 1.0, 1.0)
        else:
            st, _ = ops.la_decode_step(st, q, k, v, 1.0, 1.0)
    return st


# ---------------------------------------------------------------------------
# Recurrent families: linear / gla
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [F32, jnp.bfloat16])
def test_linear_fused_matches_unfused(impl, g, dtype):
    b, hkv, d = 3, 2, 8
    h = hkv * g
    st = _warm_state(b, hkv, d)
    q = _rand(0, (b, h, d), dtype) * 0.3
    k = _rand(1, (b, hkv, d), dtype) * 0.3
    v = _rand(2, (b, hkv, d), dtype)
    st_u, o_u = ops.la_decode_step(st, q, k, v, 1.0, 1.0)
    st_f, o_f = ops.la_decode_step_fused(st, q, k, v, backend=impl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(st_f.s), np.asarray(st_u.s),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_f.p), np.asarray(st_u.p),
                               rtol=tol, atol=tol)
    assert o_f.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(o_f, np.float32),
                               np.asarray(o_u, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [F32, jnp.bfloat16])
def test_gla_fused_matches_unfused(impl, g, dtype):
    b, hkv, d = 3, 2, 8
    h = hkv * g
    st = _warm_state(b, hkv, d, gated=True)
    q = _rand(0, (b, h, d), dtype) * 0.3
    k = _rand(1, (b, hkv, d), dtype) * 0.3
    v = _rand(2, (b, hkv, d), dtype)
    ld = -jnp.abs(_rand(3, (b, hkv))) * 0.1
    st_u, o_u = ops.gla_decode_step(st, q, k, v, ld, 1.0, 1.0)
    st_f, o_f = ops.gla_decode_step_fused(st, q, k, v, ld, backend=impl)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(st_f.s), np.asarray(st_u.s),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_f.p), np.asarray(st_u.p),
                               rtol=tol, atol=tol)
    assert o_f.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(o_f, np.float32),
                               np.asarray(o_u, np.float32),
                               rtol=tol, atol=tol)


def test_fused_state_dtype_stays_f32():
    """The carried state is f32 by contract even when q/k/v are bf16."""
    b, hkv, d = 2, 2, 8
    st = _warm_state(b, hkv, d)
    args = [_rand(i, (b, hkv, d), jnp.bfloat16) for i in range(3)]
    st_f, _ = ops.la_decode_step_fused(st, *args,
                                       backend="pallas_interpret")
    assert st_f.s.dtype == F32 and st_f.p.dtype == F32


# ---------------------------------------------------------------------------
# Attention families: softmax (contiguous) / paged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [F32, jnp.bfloat16])
def test_softmax_fused_matches_unfused(impl, g, dtype):
    b, hkv, d, n = 3, 2, 8, 50
    h = hkv * g
    q = _rand(0, (b, h, 1, d), dtype) * 0.3
    k = _rand(1, (b, hkv, n, d), dtype) * 0.3
    v = _rand(2, (b, hkv, n, d), dtype)
    lens = jnp.array([1, 12, n], jnp.int32)  # ragged, all >= 1
    o_u = ops.softmax_decode(q, k, v, lens, backend="xla")
    o_f = ops.softmax_decode_fused(q, k, v, lens, backend=impl)
    assert o_f.dtype == q.dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_f, np.float32),
                               np.asarray(o_u, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block_k", [7, 16, 64])
def test_softmax_fused_tile_tail(block_k):
    """Non-dividing block_k: the padded tail past the true S must be
    masked, not streamed into the online softmax."""
    from repro.kernels import decode_fused as df
    b, h, hkv, d, n = 2, 4, 2, 8, 50
    q = _rand(0, (b, h, 1, d)) * 0.3
    k = _rand(1, (b, hkv, n, d)) * 0.3
    v = _rand(2, (b, hkv, n, d))
    lens = jnp.array([5, n], jnp.int32)
    o_u = ops.softmax_decode(q, k, v, lens, backend="xla")
    o_f = df.softmax_decode_fused_pallas(q, k, v, lens, block_k=block_k,
                                         interpret=True)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_u),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("dtype", [F32, jnp.bfloat16])
def test_paged_fused_matches_unfused(impl, g, dtype):
    b, hkv, ps, d, pmax = 3, 2, 8, 8, 5
    h = hkv * g
    num_pages = b * pmax + 1  # page 0 is the sink
    q = _rand(0, (b, h, 1, d), dtype) * 0.3
    kp = _rand(1, (num_pages, hkv, ps, d), dtype) * 0.3
    vp = _rand(2, (num_pages, hkv, ps, d), dtype)
    pt = 1 + jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
    lens = jnp.array([1, 12, pmax * ps], jnp.int32)
    o_u = ops.paged_attention(q, kp, vp, pt, lens, backend="xla")
    o_f = ops.paged_attention_fused(q, kp, vp, pt, lens, backend=impl)
    assert o_f.dtype == q.dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_f, np.float32),
                               np.asarray(o_u, np.float32),
                               rtol=tol, atol=tol)


def test_paged_fused_ppb_tail():
    """pages_per_block=2 with an odd page count: the virtual page in the
    last block must contribute nothing."""
    from repro.kernels import decode_fused as df
    b, h, hkv, ps, d, pmax = 2, 4, 2, 8, 8, 5
    num_pages = b * pmax + 1
    q = _rand(0, (b, h, 1, d)) * 0.3
    kp = _rand(1, (num_pages, hkv, ps, d)) * 0.3
    vp = _rand(2, (num_pages, hkv, ps, d))
    pt = 1 + jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
    lens = jnp.array([12, pmax * ps], jnp.int32)
    o_u = ops.paged_attention(q, kp, vp, pt, lens, backend="xla")
    for ppb in (2, 3):
        o_f = df.paged_decode_fused_pallas(q, kp, vp, pt, lens,
                                           pages_per_block=ppb,
                                           interpret=True)
        np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_u),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Registry + dispatch
# ---------------------------------------------------------------------------

def test_fused_families_fully_registered():
    for family in ("linear_decode_fused", "gla_decode_fused",
                   "softmax_decode_fused", "paged_decode_fused"):
        names = set(ops.kernel_names(family))
        assert {"xla", "pallas", "pallas_interpret", "ref"} <= names, \
            (family, names)


def test_fused_xla_is_identical_composition():
    """The claim the decode bench relies on: on xla the fused entry
    points resolve to the very composition fused_decode=False runs."""
    b, hkv, d = 2, 2, 8
    st = _warm_state(b, hkv, d)
    q, k, v = (_rand(i, (b, hkv, d)) for i in range(3))
    st_u, o_u = ops.la_decode_step(st, q, k, v, 1.0, 1.0)
    st_f, o_f = ops.la_decode_step_fused(st, q, k, v, backend="xla")
    assert np.array_equal(np.asarray(o_f), np.asarray(o_u))
    assert np.array_equal(np.asarray(st_f.s), np.asarray(st_u.s))


# ---------------------------------------------------------------------------
# Engine level: greedy identity, donation, sampling fast path
# ---------------------------------------------------------------------------

def _params(cfg):
    from repro.models import model as mdl
    return mdl.init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("backend", ["linear", "gla", "softmax"])
def test_engine_greedy_identity_fused_vs_unfused(backend):
    cfg = backend_cfg(backend)
    assert_engine_identity(cfg, _params(cfg), {}, {"fused_decode": False})


def test_engine_greedy_identity_fused_vs_unfused_paged():
    from repro.configs.base import PagingCfg
    cfg = backend_cfg("softmax", paging=PagingCfg(page_size=16,
                                                  num_pages=32))
    assert_engine_identity(cfg, _params(cfg), {}, {"fused_decode": False})


def test_engine_decode_donates_cache():
    """The jitted decode step must alias the cache buffers in place —
    a regression here doubles decode HBM residency."""
    from repro.analysis.hlo import assert_cache_donation
    from repro.serve.engine import Engine, Request
    cfg = backend_cfg("linear")
    eng = Engine(cfg, _params(cfg), max_len=32, eos_id=-1)
    eng.submit(Request(rid=0, prompt=[3, 4, 5], max_new_tokens=2))
    eng.run()
    compiled = eng._decode.lower(
        eng.params, eng.cache, jnp.asarray(eng.next_tokens),
        jnp.asarray(eng._keys), jnp.asarray(eng._temp),
        jnp.asarray(eng._topk), jnp.asarray(eng._topp)).compile()
    assert_cache_donation(compiled)


def test_sampling_greedy_fast_path_keys_and_tokens():
    """All-greedy batches must return argmax tokens WITHOUT consuming
    PRNG state; mixed batches still advance every key."""
    from repro.serve.sampling import sample
    b, v = 4, 16
    logits = _rand(0, (b, v))
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b, dtype=jnp.uint32))
    temp0 = jnp.zeros((b,))
    topk = jnp.zeros((b,), jnp.int32)
    topp = jnp.ones((b,))
    toks, nk = jax.jit(sample)(logits, keys, temp0, topk, topp)
    assert np.array_equal(np.asarray(toks),
                          np.asarray(jnp.argmax(logits, -1)))
    assert np.array_equal(np.asarray(nk), np.asarray(keys))
    # different keys, same greedy batch -> identical tokens
    keys2 = jax.vmap(jax.random.PRNGKey)(
        jnp.arange(100, 100 + b, dtype=jnp.uint32))
    toks2, _ = jax.jit(sample)(logits, keys2, temp0, topk, topp)
    assert np.array_equal(np.asarray(toks), np.asarray(toks2))
    # mixed batch: keys advance, the greedy row still gets argmax
    tmix = temp0.at[1].set(0.8)
    toks3, nk3 = jax.jit(sample)(logits, keys, tmix, topk, topp)
    assert int(toks3[0]) == int(jnp.argmax(logits[0]))
    assert not np.array_equal(np.asarray(nk3), np.asarray(keys))
