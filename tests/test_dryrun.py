"""Multi-pod dry-run smoke: the production meshes are exercised in a
SUBPROCESS (the 512 fake host devices must be configured before jax
initializes, which cannot happen inside this pytest process).

The full 40-cell x 2-mesh matrix is launch/dryrun.py's job; here we
gate (a) reduced configs on both meshes across families, and (b) one
full-size config end-to-end, so CI catches sharding regressions.
"""
import json
import os
import subprocess
import sys

import pytest

from helpers import requires_axis_type

# every test here subprocess-runs launch/dryrun.py, which imports
# launch/mesh.py (jax.sharding.AxisType) — skip the module on old jax
pytestmark = requires_axis_type

_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import lower_cell
compiled, r = lower_cell({arch!r}, {shape!r}, multi_pod={multi}, smoke={smoke})
print("RESULT " + json.dumps({{
    "flops": r.flops_per_device, "coll": r.collective_bytes,
    "temp": r.memory_stats["temp_bytes"]}}))
"""


def _run(arch, shape, multi=False, smoke=True):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c",
         _SCRIPT.format(arch=arch, shape=shape, multi=multi, smoke=smoke)],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT "):])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b",
                                  "moonshot-v1-16b-a3b", "whisper-large-v3"])
def test_smoke_configs_lower_on_single_pod(arch):
    r = _run(arch, "train_4k", multi=False, smoke=True)
    assert r["flops"] > 0


@pytest.mark.slow
def test_smoke_config_lowers_on_multi_pod():
    r = _run("qwen2.5-3b", "train_4k", multi=True, smoke=True)
    assert r["flops"] > 0


@pytest.mark.slow
def test_full_config_lowers_and_fits():
    """One full-scale cell: compiles AND fits v5e HBM (16 GB/chip)."""
    r = _run("qwen2.5-3b", "train_4k", multi=False, smoke=False)
    assert r["flops"] > 1e13            # trip-count-corrected, per chip
    assert r["temp"] < 16e9, f"does not fit HBM: {r['temp']/1e9:.1f} GB"
    assert r["coll"] > 0                # TP/DP collectives present


_EP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import AxisType
from repro.configs.base import LACfg, ModelConfig, MoECfg
from repro.distributed.act_sharding import use_activation_policy
from repro.models import moe

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=16, vocab_size=64,
                  la=LACfg(chunk=8), compute_dtype="float32",
                  moe=MoECfg(num_experts=8, top_k=2, d_expert=16,
                             num_shared=2, capacity_factor=8.0))
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
p = moe.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
y_ref, aux_ref = moe.moe_apply(p, cfg, x)
with use_activation_policy(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_apply(p, cfg, x))(p, x)
assert float(jnp.abs(y_ep - y_ref).max()) < 1e-5
assert abs(float(aux_ep) - float(aux_ref)) < 1e-5
def loss_ep(p):
    with use_activation_policy(mesh):
        y, aux = moe.moe_apply(p, cfg, x)
    return jnp.sum(y ** 2) + 0.01 * aux
def loss_ref(p):
    y, aux = moe.moe_apply(p, cfg, x)
    return jnp.sum(y ** 2) + 0.01 * aux
g1 = jax.jit(jax.grad(loss_ep))(p)
g2 = jax.grad(loss_ref)(p)
errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2)
assert max(jax.tree.leaves(errs)) < 1e-3, errs
print("RESULT ok")
"""


@pytest.mark.slow
def test_expert_parallel_moe_matches_reference():
    """The shard_map EP dispatch (values, aux loss AND gradients) must
    equal the single-device capacity path on a real 2x4 device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _EP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESULT ok" in out.stdout
