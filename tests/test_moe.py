"""MoE layer: routing math, capacity semantics, dropless decode,
load-balance aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LACfg, ModelConfig, MoECfg
from repro.models import moe


def _cfg(num_experts=8, top_k=2, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, d_ff=16, vocab_size=64, la=LACfg(chunk=8),
        moe=MoECfg(num_experts=num_experts, top_k=top_k, d_expert=16,
                   num_shared=1, capacity_factor=cf),
        compute_dtype="float32")


def _dense_reference(p, cfg, x):
    """Dropless oracle: run every expert on every token, weight by the
    renormalized top-k gates."""
    m = cfg.moe
    b, n, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    y = jnp.zeros_like(xt)
    for e in range(m.num_experts):
        h = (jax.nn.silu(xt @ p["experts"]["wg"][e])
             * (xt @ p["experts"]["wi"][e])) @ p["experts"]["wo"][e]
        w = jnp.sum(jnp.where(expert_ids == e, gate_vals, 0.0), -1)
        y = y + w[:, None] * h
    if "shared" in p:
        for s in range(m.num_shared):
            y = y + (jax.nn.silu(xt @ p["shared"]["wg"][s])
                     * (xt @ p["shared"]["wi"][s])) @ p["shared"]["wo"][s]
    return y.reshape(b, n, d)


def test_matches_dense_reference_when_capacity_ample(rng):
    cfg = _cfg(cf=8.0)
    p = moe.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_dropless_decode_exact_even_with_tiny_capacity_factor(rng):
    cfg = _cfg(cf=0.1)  # train capacity would drop almost everything
    p = moe.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 1, 32))
    y, _ = moe.moe_apply(p, cfg, x, dropless=True)
    y_ref = _dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drops_are_partial_not_catastrophic(rng):
    cfg = _cfg(cf=0.5)
    p = moe.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32))
    y, _ = moe.moe_apply(p, cfg, x)
    y_ref = _dense_reference(p, cfg, x)
    # some tokens dropped (not equal), but shared expert keeps all finite
    assert bool(jnp.all(jnp.isfinite(y)))
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    assert 0 < rel < 1.0


def test_aux_loss_prefers_balance(rng):
    """Uniform routing should have lower aux loss than collapsed."""
    cfg = _cfg()
    p = moe.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 32))
    _, aux_uniform = moe.moe_apply(p, cfg, x)
    # collapse the router to one expert
    p2 = jax.tree.map(lambda a: a, p)
    w = np.zeros_like(np.asarray(p["router"]["w"]))
    w[:, 0] = 10.0
    p2["router"]["w"] = jnp.asarray(w)
    _, aux_collapsed = moe.moe_apply(p2, cfg, x)
    assert float(aux_collapsed) > float(aux_uniform)


def test_gradients_flow_through_router(rng):
    cfg = _cfg()
    p = moe.moe_init(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 32))

    def loss(p):
        y, aux = moe.moe_apply(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0
    assert float(jnp.abs(g["experts"]["wi"]).max()) > 0
