"""Scheduler v2 (token-interleaved + priority + preemption) invariants.

The load-bearing pins:

  * PREEMPT-RESUME IDENTITY: a preempted + resumed request's token
    stream is identical to an uninterrupted run, for every eviction
    policy — contiguous snapshot/restore, paged-softmax
    drop-and-recompute, and the gla state-page keep/swap (the paper's
    O(D^2)-state "preemption is nearly free" story);
  * priority classes order admission under contention (strict FIFO
    within a class, preempted requests resume at their original
    arrival order);
  * the per-step TokenBudget accounting is exact: decode tokens equal
    the decoding slots, prefill tokens cover every prompt token
    exactly once, and a step only overflows the budget by the one
    forced window that guarantees prefill liveness;
  * no reservation leaks: after a preemption-heavy run drains, the
    page pool is back to empty and nothing is left suspended.

Plus the request-lifecycle bugfix regressions this PR ships:
max_new_tokens=1 yields exactly one token (and <1 is rejected at
submit), empty prompts are rejected at submit instead of crashing
inside jit, and a live rid cannot be silently overwritten.
"""
import jax
import pytest

from helpers import backend_cfg
from repro.models import model as mdl
from repro.obs import ServeTracer
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import RequestState

A_PROMPT = list(range(3, 15))    # 12 tokens -> 3 windows at chunk 4
B_PROMPT = list(range(20, 24))   # 4 tokens  -> 1 window


@pytest.fixture(scope="module")
def setups():
    out = {}
    for backend in ("linear", "softmax", "gla"):
        cfg = backend_cfg(backend)
        out[backend] = (cfg, mdl.init_params(cfg, jax.random.PRNGKey(0)))
    return out


def _solo_tokens(cfg, params, req_kw, **engine_kw):
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1,
                 prefill_chunk=4, **engine_kw)
    eng.submit(Request(**req_kw))
    return eng.run()[req_kw["rid"]]


def _preempted_run(cfg, params, **engine_kw):
    """rid 0 (priority 0) decodes; rid 1 (priority 5) lands mid-stream
    on a 1-slot engine, forcing a preemption.  Returns (done, engine,
    tracer)."""
    tr = ServeTracer()
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1,
                 prefill_chunk=4, tracer=tr, **engine_kw)
    eng.submit(Request(rid=0, prompt=list(A_PROMPT), max_new_tokens=10))
    for _ in range(6):           # rid 0 well into decode
        eng.step()
    assert eng.request(0).state is RequestState.DECODING
    eng.submit(Request(rid=1, prompt=list(B_PROMPT), max_new_tokens=3,
                       priority=5))
    done = eng.run()
    assert eng.preemption_count >= 1
    return done, eng, tr


def _rec(tr, rid):
    return {r.rid: r for r in tr.records()}[rid]


def _policies(tr, rid):
    return [p for _, _, p in _rec(tr, rid).preempt_events]


# ---------------------------------------------------------------------------
# Preempt-resume greedy identity, per eviction policy
# ---------------------------------------------------------------------------

def test_preempt_resume_identity_linear_snapshot(setups):
    cfg, params = setups["linear"]
    solo_a = _solo_tokens(cfg, params,
                          dict(rid=0, prompt=list(A_PROMPT),
                               max_new_tokens=10))
    solo_b = _solo_tokens(cfg, params,
                          dict(rid=1, prompt=list(B_PROMPT),
                               max_new_tokens=3))
    done, eng, tr = _preempted_run(cfg, params)
    assert done[0] == solo_a and done[1] == solo_b
    assert _policies(tr, 0) == ["snapshot"] * eng.preemption_count


def test_preempt_resume_identity_softmax_snapshot(setups):
    cfg, params = setups["softmax"]
    solo_a = _solo_tokens(cfg, params,
                          dict(rid=0, prompt=list(A_PROMPT),
                               max_new_tokens=10))
    done, eng, tr = _preempted_run(cfg, params)
    assert done[0] == solo_a
    assert _policies(tr, 0) == ["snapshot"] * eng.preemption_count


def test_preempt_resume_identity_paged_softmax_recompute(setups):
    """Paged KV: the victim's pages are freed at eviction and the
    prefix is recomputed on resume — tokens still identical."""
    cfg, params = setups["softmax"]
    solo_a = _solo_tokens(cfg, params,
                          dict(rid=0, prompt=list(A_PROMPT),
                               max_new_tokens=10), page_size=8)
    done, eng, tr = _preempted_run(cfg, params, page_size=8)
    assert done[0] == solo_a
    assert _policies(tr, 0) == ["recompute"] * eng.preemption_count
    rec = tr.records()[0]
    assert rec.preemptions == eng.preemption_count
    assert rec.preempted_s is not None and rec.preempted_s > 0


def test_preempt_resume_identity_paged_gla_page_swap(setups):
    """Paged gla state: a slot-blocked preemption KEEPS the victim's
    one O(D^2) state page (the pool allocation survives), so resume is
    a single page-table swap — and the stream is identical."""
    cfg, params = setups["gla"]
    solo_a = _solo_tokens(cfg, params,
                          dict(rid=0, prompt=list(A_PROMPT),
                               max_new_tokens=10),
                          page_size=8, num_pages=4)
    done, eng, tr = _preempted_run(cfg, params, page_size=8, num_pages=4)
    assert done[0] == solo_a
    assert _policies(tr, 0) == ["page_keep"] * eng.preemption_count


def test_paged_gla_keeps_state_page_while_preempted(setups):
    """While evicted under the page_keep policy, the victim still holds
    its pool allocation — the whole point of the O(D^2) state story."""
    cfg, params = setups["gla"]
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1,
                 prefill_chunk=4, page_size=8, num_pages=4)
    eng.submit(Request(rid=0, prompt=list(A_PROMPT), max_new_tokens=10))
    for _ in range(6):
        eng.step()
    eng.submit(Request(rid=1, prompt=list(B_PROMPT), max_new_tokens=3,
                       priority=5))
    eng.step()   # preempts rid 0, admits rid 1
    assert eng.request(0).state is RequestState.PREEMPTED
    assert eng.pool.holds(0), "state page must survive the preemption"
    assert eng.pool.holds(1)
    done = eng.run()
    assert set(done) == {0, 1}
    assert eng.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# Priority ordering + preemption lifecycle surface
# ---------------------------------------------------------------------------

def test_priority_ordering_under_contention(setups):
    """One slot, five requests: higher classes drain first, FIFO within
    a class, and the preempted baseline request resumes at its original
    arrival order (ahead of the later same-class arrival)."""
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1,
                 prefill_chunk=4)
    eng.submit(Request(rid=0, prompt=list(A_PROMPT), max_new_tokens=8))
    for _ in range(5):
        eng.step()
    for rid, prio in ((1, 0), (2, 5), (3, 5), (4, 10)):
        eng.submit(Request(rid=rid, prompt=list(B_PROMPT),
                           max_new_tokens=2, priority=prio))
    finish_order = [o.rid for o in eng.stream() if o.finished]
    assert finish_order == [4, 2, 3, 0, 1]
    assert eng.preemption_count >= 1


def test_preemption_surfaces_step_output(setups):
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1,
                 prefill_chunk=4)
    eng.submit(Request(rid=0, prompt=list(A_PROMPT), max_new_tokens=8))
    for _ in range(6):
        eng.step()
    eng.submit(Request(rid=1, prompt=list(B_PROMPT), max_new_tokens=2,
                       priority=3))
    outs = list(eng.stream())
    pre = [o for o in outs if o.state is RequestState.PREEMPTED]
    assert pre and pre[0].rid == 0 and pre[0].token is None
    assert not pre[0].finished
    # the preempted request still finished, after the preemptor
    fins = [o.rid for o in outs if o.finished]
    assert fins == [1, 0]


# ---------------------------------------------------------------------------
# Token-budget accounting
# ---------------------------------------------------------------------------

def test_token_budget_accounting_per_step(setups):
    """Per step: decode spend == decoding slots, prefill spend stays
    within the remaining budget (modulo the single forced window that
    guarantees liveness), and every prompt token is prefilled exactly
    once across the run."""
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1,
                 prefill_chunk=4, token_budget=6)
    prompts = {0: list(range(3, 11)), 1: list(range(5, 13))}  # 8 + 8
    for rid, p in prompts.items():
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4))
    total_prefill = 0
    while eng.scheduler.has_work():
        decoding_before = sum(
            1 for _, r in eng.scheduler.active()
            if r.state is RequestState.DECODING)
        eng.step()
        b = eng.last_step_budget
        assert b["total"] == 6
        assert b["decode"] == decoding_before
        if b["decode"] + b["prefill"] > b["total"]:
            # only the forced liveness window may overflow
            assert b["prefill"] <= 4
        total_prefill += b["prefill"]
    assert total_prefill == sum(len(p) for p in prompts.values())


def test_token_budget_default_resolution(setups):
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=3, max_len=64, prefill_chunk=5)
    assert eng.token_budget == 3 + 5
    eng2 = Engine(cfg, params, max_slots=2, max_len=32)
    assert eng2.token_budget == 2 + 32
    with pytest.raises(ValueError, match="token_budget"):
        Engine(cfg, params, max_slots=2, max_len=32, token_budget=0)


# ---------------------------------------------------------------------------
# No reservation leak across preemption
# ---------------------------------------------------------------------------

def test_no_page_reservation_leak_after_preemption(setups):
    cfg, params = setups["softmax"]
    done, eng, _ = _preempted_run(cfg, params, page_size=8)
    assert set(done) == {0, 1}
    assert eng.pool.pages_in_use == 0
    assert eng.pool.free_pages == eng.pool.num_pages
    assert not eng._suspended and not eng._jobs


def test_no_state_page_leak_after_preemption_gla(setups):
    cfg, params = setups["gla"]
    done, eng, _ = _preempted_run(cfg, params, page_size=8, num_pages=4)
    assert set(done) == {0, 1}
    assert eng.pool.pages_in_use == 0
    assert eng.pool.free_pages == eng.pool.num_pages
    assert not eng._suspended and not eng._jobs


# ---------------------------------------------------------------------------
# Request-lifecycle bugfix regressions (satellites 1-3)
# ---------------------------------------------------------------------------

def test_max_new_tokens_one_yields_exactly_one_token(setups):
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1)
    eng.submit(Request(rid=0, prompt=list(range(3, 9)),
                       max_new_tokens=1))
    done = eng.run()
    assert len(done[0]) == 1
    req = eng.request(0)
    assert req.finish_reason == "length"
    assert eng.remaining[0] == 0   # never went negative


def test_submit_rejects_max_new_tokens_below_one(setups):
    cfg, params = setups["linear"]
    tr = ServeTracer()
    eng = Engine(cfg, params, max_slots=1, max_len=64, tracer=tr)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(rid=7, prompt=[3, 4, 5],
                               max_new_tokens=bad))
    assert not eng.scheduler.queue
    assert tr.records()[0].finish_reason == "rejected:max_new_tokens"


def test_submit_rejects_empty_prompt(setups):
    cfg, params = setups["linear"]
    tr = ServeTracer()
    eng = Engine(cfg, params, max_slots=1, max_len=64, tracer=tr)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=[], max_new_tokens=4))
    assert not eng.scheduler.queue
    assert tr.records()[0].finish_reason == "rejected:empty"


def test_submit_rejects_duplicate_live_rid(setups):
    cfg, params = setups["linear"]
    eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1)
    eng.submit(Request(rid=0, prompt=[3, 4, 5], max_new_tokens=2))
    with pytest.raises(ValueError, match="already live"):
        eng.submit(Request(rid=0, prompt=[6, 7], max_new_tokens=2))
    done = eng.run()
    assert len(done[0]) == 2
    # a FINISHED rid may be reused
    eng.submit(Request(rid=0, prompt=[6, 7], max_new_tokens=2))
    assert len(eng.run()[0]) == 2
