"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned family runs one forward + one train step on CPU with correct
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCHS, get_config
from repro.models import model as mdl
from repro.models.frontends import vision_positions_stub
from repro.optim import adamw
from repro.train.step import build_train_step

B, N = 2, 24


def _batch(cfg, key, n=N):
    batch = {"tokens": jax.random.randint(key, (B, n), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.rope_kind == "mrope":
        batch["positions"] = vision_positions_stub(B, n, grid=(1, 3, 3))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = mdl.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    hidden, aux = mdl.forward_hidden(params, cfg, batch)
    assert hidden.shape == (B, N, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden))), f"{arch}: NaN in hidden"
    logits = mdl.forward_logits(params, cfg, batch)
    assert logits.shape == (B, N, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN in logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(warmup_steps=1, total_steps=10, checkpoint_every=0)
    params = mdl.init_params(cfg, rng)
    opt = adamw.init(params)
    step = jax.jit(build_train_step(cfg, tc))
    batch = _batch(cfg, rng)
    # step_idx=1: the cosine schedule's LR at step 0 is 0 (warmup ramp)
    params2, opt2, metrics = step(params, opt, batch, 1)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero gradient"
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a.astype(jnp.float32)
                     - b.astype(jnp.float32), params, params2), 0.0)
    assert delta > 0, f"{arch}: params unchanged"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b",
                                  "moonshot-v1-16b-a3b", "zamba2-7b"])
def test_loss_decreases_on_repeated_batch(arch, rng):
    """Overfit a single batch for a few steps — loss must go down."""
    cfg = get_config(arch, smoke=True)
    tc = TrainConfig(learning_rate=3e-3, min_learning_rate=3e-3,
                     warmup_steps=0, total_steps=100, grad_clip=1.0)
    params = mdl.init_params(cfg, rng)
    opt = adamw.init(params)
    step = jax.jit(build_train_step(cfg, tc))
    batch = _batch(cfg, rng)
    losses = []
    for i in range(8):
        params, opt, metrics = step(params, opt, batch, i)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.95, f"{arch}: no learning {losses}"


def test_param_count_sane():
    """Full configs must land near their nameplate sizes."""
    # bounds follow the ASSIGNED dims (which for granite/moonshot imply
    # more params than the marketing name: e.g. granite at 52L x swiglu
    # d_ff=24576 is ~28B; the 20B gpt_bigcode original uses a non-gated
    # FFN — we implement the assignment's numbers)
    expected = {
        "qwen2.5-3b": (2.5e9, 4.0e9),
        "granite-20b": (17e9, 30e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "moonshot-v1-16b-a3b": (13e9, 30e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "zamba2-7b": (5.5e9, 9e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "stablelm-1.6b": (1.3e9, 2.1e9),
        "chatglm3-6b": (5e9, 7.5e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()


def test_backend_switch_softmax(rng):
    """Every attention arch also runs with the softmax baseline backend."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    params = mdl.init_params(cfg, rng)
    logits = mdl.forward_logits(params, cfg, _batch(cfg, rng))
    assert bool(jnp.all(jnp.isfinite(logits)))
