"""Shared test oracles and factories (consolidated test harness).

Three things every suite used to re-implement live here once:

  backend_cfg()            the tiny one-layer ModelConfig used for
                           backend-level tests (+ with_impl to swap the
                           kernel impl)
  assert_impl_parity()     the kernel family x impl parity assert loop
                           (compare every impl's output against the
                           first one, with a named error message)
  run_engine_greedy() /    the engine greedy-identity harness: build an
  assert_engine_identity() Engine, submit the canonical prompt set, run
                           to completion, compare rid -> tokens dicts

jax-version guards for the env-dependent suites (distributed / dryrun /
checkpoint need `jax.sharding.AxisType`) also live here so every skip
states the same reason.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.base import LACfg, ModelConfig

# jax.sharding.AxisType landed after 0.4.x; launch/mesh.py and
# launch/elastic.py (and everything importing them) need it
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
requires_axis_type = pytest.mark.skipif(
    not HAS_AXIS_TYPE,
    reason="jax.sharding.AxisType unavailable on this jax version "
           "(launch/mesh.py + launch/elastic.py need it)")

# the canonical engine-test prompt set: ragged lengths, none dividing
# the usual prefill windows
PROMPTS = [list(range(3, 10)), list(range(5, 17)), list(range(4, 8)),
           list(range(6, 14)), list(range(3, 12))]


def prompts():
    return [list(p) for p in PROMPTS]


# ---------------------------------------------------------------------------
# Config factory
# ---------------------------------------------------------------------------

def backend_cfg(backend: str = "linear", **kw) -> ModelConfig:
    """The tiny one-layer config backend-level tests share: d_model 32,
    4 query / 2 KV heads (GQA), xla kernel impl, chunk 8.

    `backend` is an attention_backend name ("linear" | "gla" |
    "softmax"); pass mixer="mla"/"mamba2" (plus their cfg blocks) via
    kw for the non-attention mixers.  Any field overrides via kw.
    """
    base = dict(name="t", family="dense", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                attention_backend=backend,
                la=LACfg(chunk=8, backend="xla"))
    base.update(kw)
    return ModelConfig(**base)


def with_impl(cfg: ModelConfig, impl: str) -> ModelConfig:
    """cfg with its kernel impl (cfg.la.backend) swapped."""
    import dataclasses
    return dataclasses.replace(
        cfg, la=dataclasses.replace(cfg.la, backend=impl))


# ---------------------------------------------------------------------------
# Kernel family x impl parity loop
# ---------------------------------------------------------------------------

def assert_impl_parity(fn, impls, *, rtol=2e-4, atol=2e-4, label=""):
    """Run `fn(impl)` for every impl and assert each output matches the
    first impl's (the reference — conventionally "xla").  `fn` may
    return one array or a tuple/list of arrays."""
    ref_impl, ref_out = impls[0], fn(impls[0])
    ref_leaves = jax.tree.leaves(ref_out)
    for impl in impls[1:]:
        got_leaves = jax.tree.leaves(fn(impl))
        # zip truncates: an impl returning FEWER outputs (e.g. a bwd
        # missing the log-decay gradient) must fail, not silently pass
        assert len(got_leaves) == len(ref_leaves), (
            f"{label}: {impl} returned {len(got_leaves)} outputs, "
            f"{ref_impl} returned {len(ref_leaves)}")
        for i, (got, want) in enumerate(zip(got_leaves, ref_leaves)):
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                rtol=rtol, atol=atol,
                err_msg=f"{label}[{i}]: {impl} != {ref_impl}")


# ---------------------------------------------------------------------------
# Engine greedy-identity harness
# ---------------------------------------------------------------------------

def run_engine_greedy(cfg, params, *, max_new: int = 6, max_len: int = 64,
                      reqs=None, **engine_kw):
    """Build an Engine, submit the canonical prompts (or `reqs`, a list
    of (rid, prompt, max_new) tuples), drain it, and return
    (rid -> generated tokens, engine).  eos_id defaults to -1 so runs
    always produce exactly max_new tokens (deterministic comparisons).
    """
    from repro.serve.engine import Engine, Request
    engine_kw.setdefault("eos_id", -1)
    eng = Engine(cfg, params, max_len=max_len, **engine_kw)
    if reqs is None:
        reqs = [(rid, p, max_new) for rid, p in enumerate(prompts())]
    for rid, prompt, mn in reqs:
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=mn))
    return eng.run(), eng


def assert_engine_identity(cfg, params, base_kw: dict, *variant_kws,
                           max_new: int = 6, max_len: int = 64):
    """Greedy engine outputs must be token-identical across engine
    configurations (chunked vs one-shot prefill, paged vs contiguous
    cache, kernel impls...).  Returns the base run's rid -> tokens."""
    base, _ = run_engine_greedy(cfg, params, max_new=max_new,
                                max_len=max_len, **base_kw)
    for kw in variant_kws:
        got, _ = run_engine_greedy(cfg, params, max_new=max_new,
                                   max_len=max_len, **kw)
        assert got == base, (
            f"engine outputs diverged for {kw} vs {base_kw}: "
            f"{got} != {base}")
    return base
