"""Serving correctness: prefill + decode == teacher-forced forward for
every architecture; the continuous-batching engine matches sequential
generation; cache sizes honor the paper's O(D^2) story."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config
from repro.models import model as mdl
from repro.models.frontends import vision_positions_stub
from repro.serve.cache import cache_bytes, kv_cache_bytes_analytic, \
    la_state_bytes_analytic
from repro.serve.engine import Engine, Request

B, N = 2, 17


def _batch(cfg, key, n=N):
    batch = {"tokens": jax.random.randint(key, (B, n), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.rope_kind == "mrope":
        batch["positions"] = vision_positions_stub(B, n, grid=(1, 3, 3))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = mdl.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    toks = batch["tokens"]
    full = mdl.forward_logits(params, cfg, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :N - 4]
    if "positions" in pre:
        pre["positions"] = batch["positions"][:, :, :N - 4]
    cache = mdl.init_cache(cfg, B, N + 8)
    logits, cache = mdl.prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, N - 5]),
                               rtol=1e-4, atol=1e-4)
    for i in range(N - 4, N):
        logits, cache = mdl.decode_step(params, cfg, cache, toks[:, i])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_la_cache_independent_of_context():
    """Paper's deployment claim, at the full-model level."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    assert cache_bytes(cfg, 4, 128) == cache_bytes(cfg, 4, 1 << 20)


def test_cache_bytes_comparison_full_scale():
    """At 32k context the paper's LA state beats the KV cache by >100x
    (Table 1's memory story at deployment scale)."""
    cfg = get_config("qwen2.5-3b")
    kv = kv_cache_bytes_analytic(cfg, batch=1, seq=32768)
    la = la_state_bytes_analytic(cfg, batch=1)
    assert la * 100 < kv, (la, kv)


@pytest.mark.parametrize("backend", ["linear", "softmax"])
def test_engine_matches_sequential(backend, rng):
    """Continuous batching must not change any request's output — for
    the O(D^2)-state linear backend AND the KV-cache softmax baseline
    (slots sit at different depths, exercising per-slot positions)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend=backend)
    params = mdl.init_params(cfg, rng)
    prompts = [
        list(range(3, 10)), list(range(5, 17)), list(range(4, 8)),
        list(range(6, 14)), list(range(3, 12)),
    ]
    engine = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
    batched = engine.run()

    # sequential reference: greedy decode one request at a time
    for rid, p in enumerate(prompts):
        toks = jnp.asarray(p, jnp.int32)[None]
        cache = mdl.init_cache(cfg, 1, 64)
        logits, cache = mdl.prefill(params, cfg, {"tokens": toks}, cache)
        out = [int(jnp.argmax(logits, -1)[0])]
        for _ in range(5):
            logits, cache = mdl.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32))
            out.append(int(jnp.argmax(logits, -1)[0]))
        assert batched[rid] == out, f"request {rid}: {batched[rid]} != {out}"


def test_engine_refills_slots(rng):
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    engine = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    for rid in range(5):
        engine.submit(Request(rid=rid, prompt=[3 + rid, 4, 5],
                              max_new_tokens=3))
    done = engine.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in done.values())


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b", "zamba2-7b",
                                  "deepseek-v2-236b", "qwen2-vl-7b"])
def test_chunked_prefill_exact(arch, rng):
    """Windowed (chunked) prefill carrying the recurrent state must give
    bit-comparable logits AND cache to single-shot prefill."""
    from repro.models.frontends import vision_positions_stub
    from repro.train.step import build_prefill_step
    cfg = get_config(arch, smoke=True)
    params = mdl.init_params(cfg, rng)
    n, w = 32, 8
    batch = {"tokens": jax.random.randint(rng, (B, n), 0, cfg.vocab_size)}
    if cfg.rope_kind == "mrope":
        batch["positions"] = vision_positions_stub(B, n, grid=(1, 3, 3))
    lf, cf = build_prefill_step(cfg)(params, batch)
    lc, cc = build_prefill_step(cfg, window=w)(params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf),
                               rtol=1e-4, atol=1e-4)
    for a, b_ in zip(jax.tree.leaves(cf), jax.tree.leaves(cc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-3, atol=1e-3)
