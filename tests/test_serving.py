"""Serving correctness: prefill + decode == teacher-forced forward for
every architecture; the continuous-batching engine matches sequential
generation; per-request sampling is honored during decode; ByteBudget
admission scales with the backend; cache sizes honor the paper's O(D^2)
story."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import prompts, run_engine_greedy
from repro.configs.registry import ARCHS, get_config
from repro.models import model as mdl
from repro.models.frontends import vision_positions_stub
from repro.serve.cache import cache_bytes, kv_cache_bytes_analytic, \
    la_state_bytes_analytic, per_slot_bytes
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import ByteBudget, FixedSlots, RequestState

B, N = 2, 17


def _batch(cfg, key, n=N):
    batch = {"tokens": jax.random.randint(key, (B, n), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.rope_kind == "mrope":
        batch["positions"] = vision_positions_stub(B, n, grid=(1, 3, 3))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = mdl.init_params(cfg, rng)
    batch = _batch(cfg, rng)
    toks = batch["tokens"]
    full = mdl.forward_logits(params, cfg, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :N - 4]
    if "positions" in pre:
        pre["positions"] = batch["positions"][:, :, :N - 4]
    cache = mdl.init_cache(cfg, B, N + 8)
    logits, cache = mdl.prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, N - 5]),
                               rtol=1e-4, atol=1e-4)
    for i in range(N - 4, N):
        logits, cache = mdl.decode_step(params, cfg, cache, toks[:, i])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               rtol=1e-3, atol=1e-3)


def test_la_cache_independent_of_context():
    """Paper's deployment claim, at the full-model level."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    assert cache_bytes(cfg, 4, 128) == cache_bytes(cfg, 4, 1 << 20)


def test_cache_bytes_comparison_full_scale():
    """At 32k context the paper's LA state beats the KV cache by >100x
    (Table 1's memory story at deployment scale)."""
    cfg = get_config("qwen2.5-3b")
    kv = kv_cache_bytes_analytic(cfg, batch=1, seq=32768)
    la = la_state_bytes_analytic(cfg, batch=1)
    assert la * 100 < kv, (la, kv)


@pytest.mark.parametrize("backend", ["linear", "gla", "softmax"])
def test_engine_matches_sequential(backend, rng):
    """Continuous batching must not change any request's output — for
    the O(D^2)-state linear and decay-gated (gla) backends AND the
    KV-cache softmax baseline (slots sit at different depths,
    exercising per-slot positions)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend=backend)
    params = mdl.init_params(cfg, rng)
    prompts = [
        list(range(3, 10)), list(range(5, 17)), list(range(4, 8)),
        list(range(6, 14)), list(range(3, 12)),
    ]
    engine = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    for rid, p in enumerate(prompts):
        engine.submit(Request(rid=rid, prompt=p, max_new_tokens=6))
    batched = engine.run()

    # sequential reference: greedy decode one request at a time
    for rid, p in enumerate(prompts):
        toks = jnp.asarray(p, jnp.int32)[None]
        cache = mdl.init_cache(cfg, 1, 64)
        logits, cache = mdl.prefill(params, cfg, {"tokens": toks}, cache)
        out = [int(jnp.argmax(logits, -1)[0])]
        for _ in range(5):
            logits, cache = mdl.decode_step(
                params, cfg, cache, jnp.asarray([out[-1]], jnp.int32))
            out.append(int(jnp.argmax(logits, -1)[0]))
        assert batched[rid] == out, f"request {rid}: {batched[rid]} != {out}"


def test_engine_refills_slots(rng):
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    engine = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    for rid in range(5):
        engine.submit(Request(rid=rid, prompt=[3 + rid, 4, 5],
                              max_new_tokens=3))
    done = engine.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 3 for v in done.values())


@pytest.mark.parametrize("arch,backend", [
    ("qwen2.5-3b", "linear"), ("qwen2.5-3b", "softmax"),
    ("mamba2-2.7b", None), ("zamba2-7b", None),
    ("deepseek-v2-236b", None), ("qwen2-vl-7b", None)])
def test_chunked_prefill_exact(arch, backend, rng):
    """Windowed (chunked) prefill must give bit-comparable logits AND
    cache to single-shot prefill — for the recurrent-state backends
    (carried state) AND the softmax baseline (continuation prefill: each
    window attends to the cached prefix, not just itself)."""
    from repro.models.frontends import vision_positions_stub
    from repro.train.step import build_prefill_step
    cfg = get_config(arch, smoke=True)
    if backend is not None:
        cfg = dataclasses.replace(cfg, attention_backend=backend)
    params = mdl.init_params(cfg, rng)
    n, w = 32, 8
    batch = {"tokens": jax.random.randint(rng, (B, n), 0, cfg.vocab_size)}
    if cfg.rope_kind == "mrope":
        batch["positions"] = vision_positions_stub(B, n, grid=(1, 3, 3))
    lf, cf = build_prefill_step(cfg)(params, batch)
    lc, cc = build_prefill_step(cfg, window=w)(params, batch)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf),
                               rtol=1e-4, atol=1e-4)
    for a, b_ in zip(jax.tree.leaves(cf), jax.tree.leaves(cc)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Serving API v2: chunked prefill, per-request sampling, admission control
# ---------------------------------------------------------------------------

# the canonical engine-harness prompt set now lives in tests/helpers.py
_prompts = prompts


@pytest.mark.parametrize("backend", ["linear", "gla", "softmax"])
def test_engine_chunked_prefill_matches_oneshot(backend, rng,
                                                engine_harness):
    """Greedy engine outputs must be identical whether prompts prefill
    one-shot or window-by-window into the slot's cache region (windows
    deliberately don't divide the prompt lengths)."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend=backend)
    params = mdl.init_params(cfg, rng)
    engine_harness(cfg, params,
                   dict(max_slots=2),
                   dict(max_slots=2, prefill_chunk=5))


def test_engine_chunked_prefill_matches_oneshot_flash_kernel(rng):
    """Acceptance (flash v2): with the softmax backend's kernel impl
    forced to the flash (interpret) kernel, the engine's continuation
    prefill runs through Pallas — per-slot q_offset via scalar prefetch,
    no XLA fallback — and greedy outputs stay identical chunked vs
    one-shot AND identical to the xla impl."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    params = mdl.init_params(cfg, rng)

    def run(prefill_chunk, kernel):
        done, eng = run_engine_greedy(cfg, params, max_slots=2,
                                      prefill_chunk=prefill_chunk,
                                      kernel_backend=kernel)
        assert eng.cfg.la.backend == kernel
        return done

    flash_one = run(None, "pallas_interpret")
    flash_chunked = run(5, "pallas_interpret")
    assert flash_one == flash_chunked
    assert sorted(flash_one) == [0, 1, 2, 3, 4]
    assert all(len(v) == 6 for v in flash_one.values())
    # cross-impl (flash vs xla) token identity is deliberately NOT
    # asserted: greedy argmax over logits that differ by float rounding
    # is tie-fragile; numeric cross-impl parity lives in
    # tests/test_kernels_flash.py at the logit level


def test_decode_honors_temperature(rng):
    """Regression: engine v1 sampled every post-prefill token with
    temperature 0.0, silently ignoring the request's temperature.  A
    hot request under a fixed seed must diverge from greedy, and be
    reproducible run-to-run."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    prompt = list(range(3, 11))

    def run():
        eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
        eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=8,
                           sampling=SamplingParams(temperature=5.0,
                                                   seed=7)))
        return eng.run()

    first, second = run(), run()
    assert first[0] != first[1], "high-temperature request decoded greedily"
    assert first == second, "seeded sampling must be reproducible"
    assert len(first[1]) == 8


def test_sampling_independent_of_batch_neighbors(rng):
    """A seeded request's tokens depend only on its own key — not on
    which other requests share the batch (per-request PRNG streams)."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    sp = SamplingParams(temperature=2.0, seed=11)

    def run(extra_hot):
        eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
        eng.submit(Request(rid=0, prompt=list(range(3, 9)),
                           max_new_tokens=6, sampling=sp))
        other = SamplingParams(temperature=3.0, seed=5) if extra_hot \
            else SamplingParams()
        eng.submit(Request(rid=1, prompt=list(range(4, 12)),
                           max_new_tokens=6, sampling=other))
        return eng.run()

    assert run(False)[0] == run(True)[0]


def test_top_k_one_is_greedy(rng):
    """top_k=1 collapses sampling to argmax even at high temperature."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    prompt = list(range(3, 11))

    def run(sampling):
        eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=-1)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                           sampling=sampling))
        return eng.run()[0]

    greedy = run(SamplingParams())
    assert run(SamplingParams(temperature=4.0, top_k=1, seed=3)) == greedy


def test_finish_reasons_and_stop_tokens(rng):
    """length / eos / SamplingParams.stop all finish with the right
    reason, and stop cuts generation short MID-DECODE (seeded sampling
    gives a reproducible, non-repeating token stream to stop on)."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    prompt = list(range(3, 11))
    hot = SamplingParams(temperature=5.0, seed=13)

    def run(eos_id, sampling):
        eng = Engine(cfg, params, max_slots=1, max_len=64, eos_id=eos_id)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8,
                           sampling=sampling))
        return eng.run()[0], eng.request(0)

    full, req = run(-1, hot)
    assert req.finish_reason == "length"
    assert req.state is RequestState.FINISHED
    assert len(full) == 8

    # a token whose FIRST occurrence is after the prefill token, so the
    # stop fires inside the jitted decode loop, not at admission
    stop_tok = next(t for t in full[1:] if t != full[0])
    cut = full.index(stop_tok) + 1
    assert cut >= 2

    got, req2 = run(stop_tok, hot)            # via eos_id
    assert got == full[:cut]
    assert req2.finish_reason == "stop"

    got, req3 = run(-1, dataclasses.replace(hot, stop=(stop_tok,)))
    assert got == full[:cut]                  # via SamplingParams.stop
    assert req3.finish_reason == "stop"


def test_stream_surfaces_lifecycle(rng):
    """stream() yields one StepOutput per generated token, transitions
    end in FINISHED, and matches run()'s results."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    for rid, p in enumerate(_prompts()[:3]):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4))
    outs = list(eng.stream())
    by_rid = {}
    for o in outs:
        by_rid.setdefault(o.rid, []).append(o)
    assert sorted(by_rid) == [0, 1, 2]
    for rid, os_ in by_rid.items():
        assert [o.finished for o in os_] == [False] * 3 + [True]
        assert os_[-1].state is RequestState.FINISHED
        assert os_[-1].finish_reason == "length"
        assert [o.token for o in os_] == eng.request(rid).generated


def test_byte_budget_admission_scales_with_backend(rng):
    """Acceptance: at the SAME byte budget the linear backend runs
    strictly more concurrent sequences than softmax, and neither exceeds
    the budget (verified with serve/cache.cache_bytes)."""
    max_len = 512
    cfg_lin = get_config("qwen2.5-3b", smoke=True)
    cfg_sm = dataclasses.replace(cfg_lin, attention_backend="softmax")
    budget = 6 * per_slot_bytes(cfg_sm, max_len)   # a handful of KV slots
    slots = {}
    for name, cfg in (("linear", cfg_lin), ("softmax", cfg_sm)):
        policy = ByteBudget(budget)
        n = policy.resolve_slots(cfg, max_len)
        marginal = cache_bytes(cfg, n, max_len) - cache_bytes(cfg, 0,
                                                              max_len)
        assert marginal <= budget, (name, marginal, budget)
        slots[name] = n
    assert slots["linear"] > slots["softmax"], slots
    # the linear backend's O(D^2) state admits at least an order of
    # magnitude more sequences (paper Table 1's memory story, as policy)
    assert slots["linear"] >= 10 * slots["softmax"] \
        or slots["linear"] == ByteBudget(budget).max_slots


def test_byte_budget_engine_runs_and_caps_memory(rng):
    """An engine under ByteBudget admission completes all requests and
    its allocated cache stays within the budget."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    params = mdl.init_params(cfg, rng)
    budget = 3 * per_slot_bytes(cfg, 64) + per_slot_bytes(cfg, 64) // 2
    eng = Engine(cfg, params, max_len=64, eos_id=-1,
                 policy=ByteBudget(budget))
    assert eng.num_slots == 3
    assert cache_bytes(cfg, eng.num_slots, 64) - cache_bytes(cfg, 0, 64) \
        <= budget
    for rid, p in enumerate(_prompts()):
        eng.submit(Request(rid=rid, prompt=p, max_new_tokens=3))
    done = eng.run()
    assert sorted(done) == [0, 1, 2, 3, 4]


def test_per_slot_bytes_charges_kv_heads_not_query_heads():
    """Regression (GQA admission accounting): a grouped-query softmax
    slot costs Hkv KV heads, so ByteBudget's per-slot charge must not
    scale with the QUERY head count — and must match the Hkv analytic
    formula at the engine's actual compute dtype (the old analytic
    helper hardcoded 2-byte elements, which under f32 read like an
    H-head charge on the group-2 configs)."""
    base = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                               attention_backend="softmax", head_dim=16)
    max_len = 256
    g2 = dataclasses.replace(base, num_heads=4, num_kv_heads=2)
    g4 = dataclasses.replace(base, num_heads=8, num_kv_heads=2)
    mha = dataclasses.replace(base, num_heads=4, num_kv_heads=4)
    # doubling the query heads at fixed Hkv must not change the charge
    assert per_slot_bytes(g2, max_len) == per_slot_bytes(g4, max_len)
    # doubling Hkv doubles the KV portion (the pos counter is 4 bytes)
    kv2 = per_slot_bytes(g2, max_len) - 4
    kv4 = per_slot_bytes(mha, max_len) - 4
    assert kv4 == 2 * kv2
    # analytic == exact at the config's own compute dtype
    assert kv2 == kv_cache_bytes_analytic(g2, 1, max_len)
    itemsize = 4 if g2.compute_dtype == "float32" else 2
    assert kv2 == (2 * g2.num_kv_heads * max_len * 16 * itemsize
                   * g2.num_layers)


def test_byte_budget_rejects_impossible_budget():
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    with pytest.raises(ValueError, match="cannot admit"):
        ByteBudget(budget_bytes=16).resolve_slots(cfg, 512)


def test_top_p_zero_keeps_top1():
    """top_p=0 must degenerate to argmax, never to an all--inf row."""
    from repro.serve.sampling import sample
    logits = jnp.asarray([[1.0, 5.0, 2.0, 0.5]])
    keys = jnp.asarray(np.stack([np.asarray(jax.random.PRNGKey(0))]))
    toks, _ = sample(logits, keys, jnp.asarray([2.0]),
                     jnp.asarray([0], jnp.int32), jnp.asarray([0.0]))
    assert int(toks[0]) == 1


def test_submit_rejects_requests_beyond_max_len(rng):
    """A prompt + generation that cannot fit the engine's cache is
    rejected at submit, not silently corrupted at the cache tail."""
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    params = mdl.init_params(cfg, rng)
    eng = Engine(cfg, params, max_slots=1, max_len=16, eos_id=-1,
                 prefill_chunk=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(rid=0, prompt=list(range(3, 27)),
                           max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=list(range(3, 15)),
                       max_new_tokens=5))  # 12 + 5 - 1 = 16 fits exactly
    assert len(eng.run()[1]) == 5


def test_fifo_drain_order(rng):
    """Queued requests drain in FIFO order as slots free: with one slot
    and equal-length work, finish order == submission order."""
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = mdl.init_params(cfg, rng)
    eng = Engine(cfg, params, max_len=64, eos_id=-1,
                 policy=FixedSlots(1))
    for rid in range(4):
        eng.submit(Request(rid=rid, prompt=[3 + rid, 4, 5],
                           max_new_tokens=2))
    finish_order = [o.rid for o in eng.stream() if o.finished]
    assert finish_order == [0, 1, 2, 3]
