"""Observability (repro.obs): metrics math against a numpy oracle,
tracer-disabled engine identity, span completeness, Chrome-trace and
Prometheus exposition schemas, the head-of-line stall baseline, and the
BENCH_serve.json schema gate.

The load-bearing pins:

  * traced engine output is TOKEN-IDENTICAL to untraced — tracing may
    never change what the engine computes;
  * Histogram.percentile (bucketed) brackets the exact inverted-CDF
    percentile within one BUCKET_RATIO — the snapshot-only derivation
    the metrics artifact relies on;
  * a long prompt's chunked prefill stalls a co-resident request's
    decode, so inter-token p99 >> p50 — the baseline number the
    scheduler roadmap item is judged against.
"""
import gc
import json
import math
import re

import jax
import numpy as np
import pytest

from helpers import backend_cfg, run_engine_greedy
from repro.models import model as mdl
from repro.obs import (BUCKET_RATIO, LATENCY_BUCKETS, Counter, Gauge,
                       Histogram, MetricsRegistry, RequestRecord,
                       ServeTracer, Tracer, log_buckets, percentiles)
from repro.serve.engine import Engine, Request
from repro.serve.paging import PagePool
from repro.serve.scheduler import RequestState


def _exact_pct(xs, p):
    """Oracle: inverted-CDF order statistic, independent impl."""
    xs = sorted(xs)
    return xs[max(1, math.ceil(p / 100.0 * len(xs))) - 1]


# ---------------------------------------------------------------------------
# metrics: instruments + percentile math
# ---------------------------------------------------------------------------

def test_percentiles_match_oracle():
    rng = np.random.default_rng(0)
    data = rng.lognormal(-6.0, 1.5, size=501).tolist()
    got = percentiles(data, (0, 50, 90, 99, 100))
    for p, v in got.items():
        assert v == _exact_pct(data, p), p
    assert got[100] == max(data)
    assert percentiles([], (50,)) == {50: None}
    with pytest.raises(ValueError):
        percentiles([1.0], (101,))


def test_histogram_percentile_brackets_exact():
    """Bucketed percentile == upper bound of the rank's bucket: at
    least the exact value, at most BUCKET_RATIO times it."""
    rng = np.random.default_rng(1)
    data = rng.lognormal(-5.0, 1.2, size=700).tolist()  # inside bounds
    h = Histogram("h")
    for v in data:
        h.observe(v)
    for p in (50, 90, 99):
        exact = _exact_pct(data, p)
        got = h.percentile(p)
        assert exact <= got <= exact * BUCKET_RATIO * (1 + 1e-12), \
            (p, exact, got)


def test_histogram_edges():
    h = Histogram("h")
    assert h.percentile(50) is None  # empty
    h.observe(1e9)                   # overflow bucket
    assert h.percentile(99) == math.inf
    snap = h.snapshot()
    assert snap["count"] == 1
    assert snap["buckets"] == [[None, 1]]  # None upper bound == +Inf
    with pytest.raises(ValueError):
        Histogram("bad", buckets=[2.0, 1.0])


def test_log_buckets_spec():
    bs = log_buckets()
    assert tuple(bs) == LATENCY_BUCKETS
    assert bs[0] == pytest.approx(1e-5) and bs[-1] == pytest.approx(100.0)
    ratios = [b / a for a, b in zip(bs, bs[1:])]
    assert all(r == pytest.approx(BUCKET_RATIO) for r in ratios)


def test_counter_gauge_registry():
    m = MetricsRegistry()
    c = m.counter("c", "help")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = m.gauge("g")
    assert g.value is None
    g.set(7)
    assert g.value == 7.0
    # get-or-create returns the same instrument; kind mismatch raises
    assert m.counter("c") is c
    with pytest.raises(TypeError):
        m.gauge("c")
    assert len(m) == 2
    doc = m.to_json()
    assert doc["version"] == 1
    assert doc["metrics"]["c"] == {"kind": "counter", "value": 3.5}


def test_prometheus_exposition_format():
    m = MetricsRegistry()
    m.counter("serve_tokens_total", "tokens").inc(5)
    m.gauge("serve_slots_active")  # never set -> NaN
    h = m.histogram("serve_ttft_seconds", "ttft")
    h.observe(0.01)
    h.observe(0.02)
    text = m.prometheus_text()
    assert "# TYPE serve_tokens_total counter\n" in text
    assert "\nserve_tokens_total 5\n" in text
    assert "\nserve_slots_active NaN\n" in text
    # cumulative le-buckets, +Inf terminal, sum/count
    assert re.search(r'serve_ttft_seconds_bucket\{le="\+Inf"\} 2\n', text)
    assert re.search(r"serve_ttft_seconds_sum 0\.03\b", text)
    assert re.search(r"serve_ttft_seconds_count 2\n", text)
    les = [float(x) for x in
           re.findall(r'serve_ttft_seconds_bucket\{le="([\d.e+-]+)"\}',
                      text)]
    assert les == sorted(les)
    counts = [int(x) for x in
              re.findall(r'serve_ttft_seconds_bucket\{le="[^"]+"\} (\d+)',
                         text)]
    assert counts == sorted(counts)  # cumulative


# ---------------------------------------------------------------------------
# tracer vs engine: identity, spans, lifecycle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def linear_setup():
    cfg = backend_cfg("linear")
    return cfg, mdl.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def softmax_setup():
    cfg = backend_cfg("softmax")
    return cfg, mdl.init_params(cfg, jax.random.PRNGKey(0))


def test_traced_engine_identity_linear(linear_setup):
    """Tracing may never change what the engine computes: token streams
    with a tracer installed are byte-identical to the untraced run,
    one-shot AND chunked prefill."""
    from helpers import assert_engine_identity
    cfg, params = linear_setup
    assert_engine_identity(
        cfg, params, {"max_slots": 2},
        {"max_slots": 2, "tracer": ServeTracer()},
        {"max_slots": 2, "prefill_chunk": 5, "tracer": ServeTracer()})


def test_traced_engine_identity_softmax_paged(softmax_setup):
    from helpers import assert_engine_identity
    cfg, params = softmax_setup
    assert_engine_identity(
        cfg, params, {"max_slots": 2, "page_size": 8},
        {"max_slots": 2, "page_size": 8, "tracer": ServeTracer()})


def test_span_completeness(linear_setup):
    """Every request that ran to completion has a full, ordered span
    tree: submit <= queued <= admitted <= first token <= finish, all
    tokens stamped, prefill windows covering the whole prompt."""
    cfg, params = linear_setup
    tr = ServeTracer()
    done, eng = run_engine_greedy(cfg, params, max_slots=2,
                                  prefill_chunk=5, tracer=tr)
    recs = tr.records()
    assert len(recs) == len(done)
    for rec in recs:
        assert rec.closed
        assert rec.finish_reason in ("stop", "length")
        assert rec.submit_t <= rec.queued_t <= rec.admitted_t
        assert rec.admitted_t <= rec.first_token_t <= rec.finish_t
        assert rec.tokens == len(done[rec.rid])
        assert list(rec.token_ts) == sorted(rec.token_ts)
        assert sum(n for _, _, n in rec.prefill_windows) == rec.prompt_len
        for t0, t1, _ in rec.prefill_windows:
            assert t1 >= t0
        assert rec.ttft_s > 0 and rec.queue_wait_s >= 0
        assert rec.total_s >= rec.decode_s >= 0
    s = tr.summary()
    assert s["finished"] == s["requests"] == len(recs)
    assert s["tokens"] == sum(len(v) for v in done.values())
    assert s["ttft_ms"]["p50"] is not None
    assert s["ttft_ms"]["p99"] is not None
    assert 0 < s["occupancy"] <= 1
    # metrics agree with the records
    m = tr.metrics
    assert m.get("serve_requests_finished_total").value == len(recs)
    assert m.get("serve_tokens_total").value == s["tokens"]
    assert m.get("serve_ttft_seconds").total == len(recs)


def test_step_output_timestamps_and_finish(linear_setup):
    """Satellite 1: StepOutput.t is a non-decreasing timer.now stamp,
    and finish outputs carry the scheduler's release stamp, which also
    lands on Request.finish_t / finish_reason."""
    cfg, params = linear_setup
    eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1)
    eng.submit(Request(rid=0, prompt=list(range(3, 9)), max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=list(range(4, 12)), max_new_tokens=3))
    outs = list(eng.stream())
    ts = [o.t for o in outs]
    assert all(b >= a for a, b in zip(ts, ts[1:]))
    fins = {o.rid: o for o in outs if o.finished}
    assert set(fins) == {0, 1}
    for rid, out in fins.items():
        req = eng.request(rid)
        assert req.state is RequestState.FINISHED
        assert req.finish_t == out.t
        assert req.finish_reason == out.finish_reason == "length"


def test_decode_stall_inter_token_p99(linear_setup):
    """The head-of-line scenario PR 9 pinned as a stall (inter-token
    p99 > 5x p50 under the FIFO scheduler, which ran ALL of a long
    prompt's prefill windows inside one step): under scheduler v2 the
    token budget interleaves at most ~one prefill window with each
    decode step, so the mid-stream long-prompt injection no longer
    blows up the short request's tail — p99 stays within 2x p50.

    Timing-test hygiene: Python GC is paused over the measured steps
    (a gen-2 collection costs a few ms — several inter-token periods
    at this model size), and the bound gets two attempts.  A genuine
    head-of-line stall is STRUCTURAL — the admission step runs every
    window back-to-back, several ms extra on one delta, every attempt
    — while a stray OS/scheduler hiccup is transient."""
    cfg, params = linear_setup

    def attempt():
        tr = ServeTracer()
        eng = Engine(cfg, params, max_slots=2, max_len=64, eos_id=-1,
                     prefill_chunk=5, tracer=tr)
        # warm every jitted program this workload will hit (the
        # 5-token mid-prompt window, the fused 5- and 1-token FINAL
        # windows, the batched decode) so the measured deltas see
        # SCHEDULING, not one-time compile spikes
        eng.submit(Request(rid=99, prompt=list(range(3, 9)),
                           max_new_tokens=2))     # windows [5, 1]
        eng.submit(Request(rid=98, prompt=list(range(3, 13)),
                           max_new_tokens=2))     # windows [5, 5]
        eng.run()
        gc.collect()
        gc.disable()
        try:
            eng.submit(Request(rid=0, prompt=list(range(3, 9)),
                               max_new_tokens=16))
            for _ in range(8):      # rid 0 decodes at steady cadence
                eng.step()
            eng.submit(Request(rid=1, prompt=list(range(3, 33)),
                               max_new_tokens=4))   # 6 prefill windows
            while eng.scheduler.has_work():
                eng.step()
        finally:
            gc.enable()
        rec = tr.records()[0]
        assert rec.rid == 0 and rec.closed
        deltas = rec.inter_token_s
        assert len(deltas) == 15
        # the long prompt still ran all its windows — spread across
        # steps (token-interleaved), not packed into one
        long_rec = tr.records()[1]
        assert len(long_rec.prefill_windows) == 6
        # ... and the short request kept emitting tokens BETWEEN those
        # windows — the interleaving itself, not just its tail effect
        w0 = long_rec.prefill_windows[0][0]
        w1 = long_rec.prefill_windows[-1][1]
        interleaved = [t for t in rec.token_ts if w0 < t < w1]
        assert len(interleaved) >= 4, (len(interleaved),
                                       "prefill ran as one "
                                       "uninterrupted block — no "
                                       "token interleaving")
        return percentiles(deltas, (50, 99))

    ps = attempt()
    if ps[99] > 2 * ps[50]:
        ps = attempt()
    assert ps[99] <= 2 * ps[50], (ps, "head-of-line stall regressed")


def test_rejected_request_traced(linear_setup):
    cfg, params = linear_setup
    tr = ServeTracer()
    eng = Engine(cfg, params, max_slots=1, max_len=16, tracer=tr)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=list(range(3, 13)),
                           max_new_tokens=50))
    rec = tr.records()[0]
    assert rec.finish_reason == "rejected:max_len"
    assert rec.closed
    assert tr.metrics.get("serve_admission_reject_total").value == 1


def test_paged_pool_gauges_and_sink(softmax_setup):
    """Page-pool telemetry: gauges mirror the pool level, every
    finished request re-points its slot at the sink page, and the
    arena drains back to empty."""
    cfg, params = softmax_setup
    tr = ServeTracer()
    done, eng = run_engine_greedy(cfg, params, max_slots=2,
                                  page_size=8, tracer=tr)
    m = tr.metrics
    assert m.get("serve_pages_in_use").value == 0
    assert m.get("serve_pages_free").value == eng.pool.num_pages
    assert m.get("serve_sink_repoints_total").value == len(done)
    s = tr.summary()
    assert s["finished"] == len(done)


def test_cow_fork_counter():
    tr = ServeTracer()
    pool = PagePool(8, 4, tracer=tr)
    pool.allocate(0, 10)          # 3 pages
    pool.fork(0, 1, 6)            # 1 shared + 1 copied tail
    assert tr.metrics.get("serve_page_cow_forks_total").value == 1
    assert tr.metrics.get("serve_pages_in_use").value == \
        pool.pages_in_use == 4
    pool.free(0)
    pool.free(1)
    assert tr.metrics.get("serve_pages_in_use").value == 0


def test_nil_tracer_is_inert():
    """The base Tracer is a pure protocol: every hook is a no-op and
    clock() is the repo timer."""
    t = Tracer()
    t.request_submitted(0, 1, 2)
    t.request_queued(0)
    t.request_rejected(0, "x")
    t.admission_blocked(0, "slots")
    t.request_admitted(0, 0)
    t.prefill_window(0, 0, 5, 0.0)
    t.token_emitted(0, 0)
    t.request_preempted(0, 0, "snapshot")
    t.request_resumed(0, 1, "snapshot")
    t.request_finished(0, "stop")
    t.engine_step(0.0, 1, 2, 0)
    t.pages_changed(1, 2)
    t.cow_fork()
    t.sink_repoint()
    assert isinstance(t.clock(), float)


# ---------------------------------------------------------------------------
# Chrome trace + report CLI
# ---------------------------------------------------------------------------

def test_chrome_trace_schema(linear_setup, tmp_path):
    cfg, params = linear_setup
    tr = ServeTracer()
    done, _ = run_engine_greedy(cfg, params, max_slots=2,
                                prefill_chunk=5, tracer=tr)
    path = tmp_path / "trace.json"
    doc = tr.export_chrome_trace(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(doc))
    ev = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    for e in ev:
        assert e["ph"] in ("X", "i", "M"), e
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    names = {e["name"] for e in ev if e["ph"] == "M"}
    assert {"process_name", "thread_name"} <= names
    # one request span per rid on pid 2; step spans on pid 0
    req_spans = [e for e in ev if e["ph"] == "X"
                 and e["name"].startswith("request ")]
    assert len(req_spans) == len(done)
    assert all(e["pid"] == 2 for e in req_spans)
    assert any(e["ph"] == "X" and e["pid"] == 0 and e["name"] == "step"
               for e in ev)
    # slot tracks carry the prefill windows
    assert any(e["ph"] == "X" and e["pid"] == 1
               and e["name"].startswith("prefill rid=") for e in ev)
    # embedded records round-trip for the report CLI
    assert len(doc["repro_records"]) == len(done)
    assert doc["repro_summary"]["finished"] == len(done)


def test_report_cli(linear_setup, tmp_path, capsys):
    from repro.obs.__main__ import main
    cfg, params = linear_setup
    tr = ServeTracer()
    run_engine_greedy(cfg, params, max_slots=2, tracer=tr)
    path = tmp_path / "trace.json"
    tr.export_chrome_trace(str(path))
    assert main(["report", str(path)]) == 0
    out = capsys.readouterr().out
    assert "ttft_ms" in out and "reason" in out and "length" in out
    assert main(["report", str(path), "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["summary"]["finished"] == len(doc["records"])
    # a non-trace json is a usage error, not a crash
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert main(["report", str(bad)]) == 1


# ---------------------------------------------------------------------------
# BENCH_serve.json schema gate (satellite 2)
# ---------------------------------------------------------------------------

def _serve_cell(**over):
    cell = {"impl": "linear", "backend": "linear",
            "ttft_ms": {"p50": 1.0, "p99": 2.0},
            "inter_token_ms": {"p50": 0.5, "p99": 1.5},
            "occupancy": 0.8, "preemptions": 0}
    cell.update(over)
    return cell


def test_bench_check_serve_schema():
    from repro.tune.bench_check import check_doc
    ok = {"kind": "serve_lat", "cells": [_serve_cell()]}
    assert check_doc(ok, "B") == []
    # null percentile VALUES are fine (unmeasured distribution)
    nulls = {"kind": "serve_lat", "cells": [_serve_cell(
        ttft_ms={"p50": None, "p99": None},
        inter_token_ms={"p50": None, "p99": None})]}
    assert check_doc(nulls, "B") == []
    # missing KEYS are the violation
    missing_p99 = {"kind": "serve_lat",
                   "cells": [_serve_cell(ttft_ms={"p50": 1.0})]}
    errs = check_doc(missing_p99, "B")
    assert any("ttft_ms.p99" in e for e in errs)
    no_occ = {"kind": "serve_lat", "cells": [_serve_cell()]}
    del no_occ["cells"][0]["occupancy"]
    assert any("occupancy" in e for e in check_doc(no_occ, "B"))
    # scheduler v2: the preemption count is part of the schema
    no_preempt = {"kind": "serve_lat", "cells": [_serve_cell()]}
    del no_preempt["cells"][0]["preemptions"]
    assert any("preemptions" in e for e in check_doc(no_preempt, "B"))
    bad_preempt = {"kind": "serve_lat",
                   "cells": [_serve_cell(preemptions="two")]}
    assert any("preemptions" in e for e in check_doc(bad_preempt, "B"))
    not_dict = {"kind": "serve_lat",
                "cells": [_serve_cell(inter_token_ms=3.0)]}
    assert any("inter_token_ms" in e for e in check_doc(not_dict, "B"))
    # without the serve_lat kind the roofline contract applies instead
    legacy = {"cells": [_serve_cell()]}
    assert any("roofline" in e for e in check_doc(legacy, "B"))


def test_bench_check_cli_on_artifact(tmp_path):
    from repro.tune.bench_check import main
    doc = {"kind": "serve_lat", "cells": [_serve_cell()]}
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(doc))
    assert main([str(p)]) == 0
    doc["cells"][0].pop("occupancy")
    p.write_text(json.dumps(doc))
    assert main([str(p)]) == 1
