"""Checkpoint store: atomicity, integrity hashes, async save, restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import requires_axis_type
from repro.checkpoint import store


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"c": jnp.arange(10, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), t, step=3)
    out, step = store.restore(str(tmp_path), t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_picks_newest(tmp_path):
    t = _tree()
    store.save(str(tmp_path), t, step=1)
    store.save(str(tmp_path), t, step=5)
    assert store.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path):
    t = _tree()
    _, thread = store.save(str(tmp_path), t, step=2, blocking=False)
    thread.join()
    assert store.latest_step(str(tmp_path)) == 2


def test_corruption_detected(tmp_path):
    t = _tree()
    ckpt = store.save(str(tmp_path), t, step=1)
    # flip bytes in one leaf
    leaf = os.path.join(ckpt, "leaf_00000.npy")
    data = bytearray(open(leaf, "rb").read())
    data[-1] ^= 0xFF
    open(leaf, "wb").write(bytes(data))
    with pytest.raises(IOError, match="hash mismatch"):
        store.restore(str(tmp_path), t)


def test_incomplete_save_invisible(tmp_path):
    """A crash mid-save (tmp dir, no manifest) must not be restorable."""
    t = _tree()
    store.save(str(tmp_path), t, step=1)
    # simulate a crashed save at step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert store.latest_step(str(tmp_path)) == 1


def test_structure_mismatch_rejected(tmp_path):
    t = _tree()
    store.save(str(tmp_path), t, step=1)
    with pytest.raises(ValueError, match="leaves"):
        store.restore(str(tmp_path), {"only": jnp.zeros(3)})


@requires_axis_type
def test_restore_with_shardings(tmp_path):
    """Elastic path: leaves land with the sharding passed at restore."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    t = _tree()
    store.save(str(tmp_path), t, step=1)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out, _ = store.restore(str(tmp_path), t, shardings=sh)
    assert out["a"].sharding == NamedSharding(mesh, P())


def test_manifest_contents(tmp_path):
    t = _tree()
    ckpt = store.save(str(tmp_path), t, step=7)
    man = json.load(open(os.path.join(ckpt, "manifest.json")))
    assert man["step"] == 7
    assert len(man["leaves"]) == len(jax.tree.leaves(t))
    assert all("sha256" in leaf for leaf in man["leaves"])
