"""Paged-KV subsystem: PagePool lifecycle (alloc / free / refcount,
copy-on-write fork, exhaustion), PagedAdmission budget math, paged
decode-kernel parity (xla vs pallas-interpret vs the contiguous
registry decode), engine-level greedy identity paged vs contiguous,
FIFO blocking on pool exhaustion, and the long-context acceptance:
PagedAdmission admits an 8k request ByteBudget refuses at the same
budget."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import prompts, run_engine_greedy
from repro.configs.registry import get_config
from repro.kernels import ops
from repro.kernels.paged_attention import gather_pages
from repro.models import model as mdl
from repro.serve.cache import page_bytes, per_slot_bytes, \
    state_page_bytes
from repro.serve.engine import Engine, Request
from repro.serve.paging import PagedAdmission, PagePool, PoolExhausted
from repro.serve.scheduler import ByteBudget, RequestState


def _softmax_cfg(**over):
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="softmax")
    return dataclasses.replace(cfg, **over) if over else cfg


def _gla_cfg(**over):
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True),
                              attention_backend="gla")
    return dataclasses.replace(cfg, **over) if over else cfg


# the canonical engine-harness prompt set now lives in tests/helpers.py
_prompts = prompts


# ---------------------------------------------------------------------------
# PagePool lifecycle
# ---------------------------------------------------------------------------

def test_pool_alloc_free_refcount():
    pool = PagePool(num_pages=8, page_size=16)
    assert pool.free_pages == 8
    a = pool.allocate(rid=0, num_tokens=40)      # ceil(40/16) = 3 pages
    assert len(a) == 3 and pool.pages_in_use == 3
    assert all(pool.refcount(p) == 1 for p in a)
    assert pool.table(0) == a
    b = pool.allocate(rid=1, num_tokens=16)      # exactly one page
    assert len(b) == 1 and set(b).isdisjoint(a)
    freed = pool.free(0)
    assert sorted(freed) == sorted(a)
    assert pool.free_pages == 7
    assert all(pool.refcount(p) == 0 for p in a)
    # LIFO free list: the most recently freed page is reused first
    c = pool.allocate(rid=2, num_tokens=1)
    assert c[0] == freed[-1]


def test_pool_extend_and_double_alloc():
    pool = PagePool(num_pages=4, page_size=8)
    pool.allocate(rid=0, num_tokens=8)
    assert pool.extend(rid=0, num_tokens=8) == []      # still fits
    new = pool.extend(rid=0, num_tokens=17)            # 3 pages total
    assert len(new) == 2 and len(pool.table(0)) == 3
    with pytest.raises(ValueError, match="already holds"):
        pool.allocate(rid=0, num_tokens=8)


def test_pool_exhaustion_raises_and_preserves_state():
    pool = PagePool(num_pages=4, page_size=16)
    pool.allocate(rid=0, num_tokens=33)          # 3 pages
    assert not pool.can_allocate(17)             # needs 2, only 1 free
    with pytest.raises(PoolExhausted, match="only 1"):
        pool.allocate(rid=1, num_tokens=17)
    assert pool.free_pages == 1                  # nothing leaked
    pool.allocate(rid=1, num_tokens=16)          # 1 page still works


def test_cow_fork_shares_full_pages_and_copies_tail():
    pool = PagePool(num_pages=8, page_size=16)
    src = pool.allocate(rid=0, num_tokens=40)    # 3 pages (40 tokens)
    table, copies = pool.fork(src_rid=0, dst_rid=1, shared_tokens=24)
    # 24 = 1 full page shared + 8 tokens of page 2 copied
    assert table[0] == src[0] and pool.refcount(src[0]) == 2
    assert copies == [(src[1], table[1])]
    assert table[1] not in src                   # frontier never aliased
    assert pool.refcount(src[1]) == 1 and pool.refcount(table[1]) == 1
    # freeing the parent keeps the shared page alive for the fork
    freed = pool.free(0)
    assert src[0] not in freed and pool.refcount(src[0]) == 1
    assert sorted(freed) == sorted(src[1:])
    freed = pool.free(1)
    assert src[0] in freed and pool.free_pages == 8


def test_cow_fork_page_aligned_prefix_copies_nothing():
    pool = PagePool(num_pages=8, page_size=16)
    src = pool.allocate(rid=0, num_tokens=32)    # 2 full pages
    table, copies = pool.fork(src_rid=0, dst_rid=1, shared_tokens=32)
    assert table == src and copies == []
    assert all(pool.refcount(p) == 2 for p in src)
    with pytest.raises(ValueError, match="exceeds"):
        pool.fork(src_rid=0, dst_rid=2, shared_tokens=64)


def test_cow_fork_arena_semantics():
    """Applying the fork's (src, dst) copies to an arena gives the fork
    the shared prefix content, and the fork's writes past the prefix
    never leak into the parent's pages."""
    pool = PagePool(num_pages=6, page_size=4)
    src = pool.allocate(rid=0, num_tokens=6)     # pages for 6 tokens
    arena = jnp.zeros((6, 1, 4, 2))              # (P, Hkv, ps, hd)
    for i, p in enumerate(src):                  # parent writes its kv
        arena = arena.at[p].set(float(i + 1))
    table, copies = pool.fork(src_rid=0, dst_rid=1, shared_tokens=6)
    for s, d in copies:                          # engine applies copies
        arena = arena.at[d].set(arena[s])
    np.testing.assert_array_equal(arena[table[1]], arena[src[1]])
    # fork writes token 6 (offset 2 of its tail page): parent unchanged
    arena = arena.at[table[1], :, 2].set(99.0)
    assert float(arena[src[1]].max()) == 2.0


# ---------------------------------------------------------------------------
# PagedAdmission budget math
# ---------------------------------------------------------------------------

def test_paged_admission_budget_math():
    cfg = _softmax_cfg()
    per_page = page_bytes(cfg, 16)
    pol = PagedAdmission(budget_bytes=10 * per_page + per_page // 2,
                         page_size=16)
    assert pol.resolve_num_pages(cfg) == 10      # floor, incl. the sink
    with pytest.raises(ValueError, match="sink"):
        PagedAdmission(budget_bytes=per_page, page_size=16) \
            .resolve_num_pages(cfg)


def test_page_bytes_matches_exact_marginal_cost():
    """One page's analytic bytes == the eval_shape-exact arena growth of
    one extra page (k and v, all layers)."""
    import repro.serve.cache as sc
    from repro.configs.base import PagingCfg
    cfg = _softmax_cfg(paging=PagingCfg(page_size=16, num_pages=4))
    cfg2 = _softmax_cfg(paging=PagingCfg(page_size=16, num_pages=5))
    assert sc.cache_bytes(cfg2, 1, 64) - sc.cache_bytes(cfg, 1, 64) \
        == page_bytes(cfg, 16)


# ---------------------------------------------------------------------------
# Kernel parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["pallas_interpret", "ref"])
def test_paged_kernel_parity(impl, rng):
    """Paged decode through every impl == the xla gather oracle == the
    contiguous softmax_decode on the gathered layout, under GQA, ragged
    per-slot lengths, an out-of-order page table, and a retired
    (length-0) slot."""
    b, h, hkv, d, ps, pages = 3, 4, 2, 16, 8, 10
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, h, 1, d)) * 0.5
    k_pages = jax.random.normal(ks[1], (pages, hkv, ps, d)) * 0.5
    v_pages = jax.random.normal(ks[2], (pages, hkv, ps, d))
    pt = jnp.asarray([[3, 1, 7, 9], [5, 9, 9, 9], [9, 9, 9, 9]], jnp.int32)
    lens = jnp.asarray([19, 8, 0], jnp.int32)

    o_x = ops.paged_attention(q, k_pages, v_pages, pt, lens, backend="xla")
    o_i = ops.paged_attention(q, k_pages, v_pages, pt, lens, backend=impl)
    np.testing.assert_allclose(np.asarray(o_i), np.asarray(o_x),
                               rtol=1e-6, atol=1e-6)
    assert not np.isnan(np.asarray(o_i)).any()
    np.testing.assert_array_equal(np.asarray(o_i[2]), 0.0)  # retired slot

    kc, vc = gather_pages(k_pages, pt), gather_pages(v_pages, pt)
    o_c = ops.softmax_decode(q, kc, vc, lens, backend="xla")
    live = np.asarray(lens) > 0
    np.testing.assert_allclose(np.asarray(o_i)[live], np.asarray(o_c)[live],
                               rtol=1e-6, atol=1e-6)


def test_softmax_decode_registry_matches_full_attention(rng):
    """The contiguous softmax_decode impl == last-row of full causal
    softmax attention at each slot's own depth (the inline einsum it
    replaced, now parity-pinned through the registry)."""
    b, h, hkv, d, s = 2, 4, 2, 16, 12
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, h, 1, d)) * 0.5
    k = jax.random.normal(ks[1], (b, hkv, s, d)) * 0.5
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    lens = jnp.asarray([12, 7], jnp.int32)
    o = ops.softmax_decode(q, k, v, lens, backend="xla")
    for i, n in enumerate(np.asarray(lens)):
        full = ops.softmax_attention(
            jnp.broadcast_to(q[i:i + 1], (1, h, 1, d)),
            k[i:i + 1, :, :n], v[i:i + 1, :, :n],
            causal=True, backend="xla",
            q_offset=jnp.asarray([n - 1], jnp.int32))
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(full[0]),
                                   rtol=1e-5, atol=1e-5)
    # unknown impl names fall back to the xla decode (no pallas
    # softmax_decode exists — the kernelized decode is the paged family)
    o_fb = ops.softmax_decode(q, k, v, lens, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(o_fb), np.asarray(o),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine-level identity + admission
# ---------------------------------------------------------------------------

# helpers.run_engine_greedy IS the old _run_engine harness, shared now
_run_engine = run_engine_greedy


@pytest.mark.parametrize("kernel", ["xla", "pallas_interpret"])
def test_engine_paged_matches_contiguous(kernel, rng):
    """Acceptance: greedy decode through the paged cache — xla gather
    AND the pallas (interpret) page-table kernel — is token-identical to
    the contiguous path, one-shot and chunked prefill alike, and every
    page returns to the free list when the queue drains."""
    cfg = _softmax_cfg()
    params = mdl.init_params(cfg, rng)
    base, _ = _run_engine(cfg, params, max_slots=2)
    paged, eng = _run_engine(cfg, params, max_slots=2, page_size=8,
                             kernel_backend=kernel)
    assert paged == base
    chunked, _ = _run_engine(cfg, params, max_slots=2, page_size=8,
                             prefill_chunk=5, kernel_backend=kernel)
    assert chunked == base
    stats = eng.page_stats()
    assert stats["pages_in_use"] == 0
    assert stats["free_pages"] == stats["num_pages"]


def test_engine_pool_exhaustion_blocks_fifo(rng):
    """Two free slots but pages for only one request: admission must
    WAIT (strict FIFO, no skipping) and admit the queued request once
    the first one's pages free — never corrupt, never deadlock."""
    cfg = _softmax_cfg()
    params = mdl.init_params(cfg, rng)
    # 2 usable pages (+1 sink); each request needs 7+6-1=12 tokens = 2
    eng = Engine(cfg, params, max_slots=2, max_len=32, eos_id=-1,
                 page_size=8, num_pages=3)
    p = list(range(3, 10))
    eng.submit(Request(rid=0, prompt=p, max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=p, max_new_tokens=6))
    events = []
    for out in eng.stream():
        events.append((out.rid, out.finished))
    finish_0 = events.index((0, True))
    first_1 = next(i for i, (rid, _) in enumerate(events) if rid == 1)
    assert first_1 > finish_0, "rid 1 must wait for rid 0's pages"
    assert eng.request(0).generated == eng.request(1).generated
    assert eng.pool.free_pages == 2

    with pytest.raises(ValueError, match="KV pages"):
        eng.submit(Request(rid=2, prompt=list(range(3, 25)),
                           max_new_tokens=4))   # > whole arena


def test_engine_paged_rejects_non_softmax_backend(rng):
    cfg = get_config("qwen2.5-3b", smoke=True)   # linear backend
    with pytest.raises(ValueError, match="softmax"):
        Engine(cfg, None, max_len=32, page_size=8)


def test_engine_paged_rejects_misconfigured_knobs():
    """ByteBudget can't size a paged engine (its per-slot charge
    collapses to the page-table row), and num_pages without page_size
    would silently serve contiguous — both fail fast."""
    cfg = _softmax_cfg()
    with pytest.raises(ValueError, match="PagedAdmission"):
        Engine(cfg, None, max_len=32, page_size=8,
               policy=ByteBudget(1 << 30))
    with pytest.raises(ValueError, match="page_size"):
        Engine(cfg, None, max_len=32, num_pages=8)
    pol = PagedAdmission(1 << 20, page_size=8)
    with pytest.raises(ValueError, match="drop the engine kwargs"):
        Engine(cfg, None, max_len=32, policy=pol, page_size=8)


# ---------------------------------------------------------------------------
# Paged recurrent state (gla) — the first non-KV layout through PagePool
# ---------------------------------------------------------------------------

def test_engine_paged_gla_state_matches_contiguous(rng):
    """ISSUE 5 acceptance: greedy decode with the GLA recurrent state
    living in a shared page arena (one state page per slot) is
    token-identical to the contiguous GLAState path — one-shot and
    chunked prefill — and slots cycling through reused pages never
    inherit a stale state (5 requests drain through 2 state pages, so
    reuse-without-zeroing would corrupt).  The paged runs set
    kernel_backend="pallas_interpret" to pin that a non-default impl
    CONFIG flows through the gla engine path (serving prefill/decode
    are the XLA recurrence for every impl, like the linear backend —
    impl parity of the kernels themselves is test_kernels_gla's job)."""
    cfg = _gla_cfg()
    params = mdl.init_params(cfg, rng)
    base, _ = _run_engine(cfg, params, max_slots=2)
    paged, eng = _run_engine(cfg, params, max_slots=2, page_size=8,
                             kernel_backend="pallas_interpret")
    assert paged == base
    chunked, _ = _run_engine(cfg, params, max_slots=2, page_size=8,
                             prefill_chunk=5,
                             kernel_backend="pallas_interpret")
    assert chunked == base
    stats = eng.page_stats()
    assert stats["pages_in_use"] == 0
    assert stats["free_pages"] == stats["num_pages"] == 2  # 1/slot


def test_gla_state_page_accounting():
    """A gla page prices one whole (Hkv, Dk, Dv+1) + (Hkv, Dv+1) f32
    state across layers — page_size-independent — and matches the
    eval_shape-exact arena growth of one extra page."""
    import repro.serve.cache as sc
    from repro.configs.base import PagingCfg
    cfg = _gla_cfg(paging=PagingCfg(page_size=16, num_pages=4))
    cfg2 = _gla_cfg(paging=PagingCfg(page_size=16, num_pages=5))
    grow = sc.cache_bytes(cfg2, 1, 64) - sc.cache_bytes(cfg, 1, 64)
    assert grow == state_page_bytes(cfg) == page_bytes(cfg, 16)
    # page_size is a KV-row notion; a state page ignores it
    assert page_bytes(cfg, 1) == page_bytes(cfg, 512)
    hd = cfg.resolved_head_dim
    want = (cfg.num_kv_heads * ((hd + 1) * hd + (hd + 1))
            * 4 * cfg.num_layers)
    assert state_page_bytes(cfg) == want


def test_gla_paged_admission_charges_one_page_per_request(rng):
    """PagedAdmission prices the gla arena in STATE pages: a budget of
    ~2.5 state pages buys exactly 2 (incl. the sink), each request
    needs ONE page whatever its token count — so a 3rd concurrent
    request must wait for a page, not for tokens."""
    cfg = _gla_cfg()
    budget = state_page_bytes(cfg) * 5 // 2
    pol = PagedAdmission(budget, page_size=8, max_slots=4)
    assert pol.resolve_num_pages(cfg) == 2       # 1 allocatable + sink
    params = mdl.init_params(cfg, rng)
    # one allocatable state page: strict-FIFO one-at-a-time service
    done, eng = _run_engine(cfg, params, policy=pol)
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert eng.pool.free_pages == eng.pool.num_pages == 1
    # the contiguous run produces the same tokens (admission changes
    # scheduling, never results)
    base, _ = _run_engine(cfg, params, max_slots=4)
    assert done == base


def test_gla_paged_long_prompt_still_one_page(rng):
    """The O(D^2) story page-granular: a LONG prompt needs the same one
    state page as a short one (KV paging would need prompt/page_size
    pages), so a budget worth ~1 state page serves a 512-token prompt."""
    cfg = _gla_cfg()
    pol = PagedAdmission(state_page_bytes(cfg) * 2, page_size=8,
                         max_slots=1)
    params = mdl.init_params(cfg, rng)
    eng = Engine(cfg, params, max_len=1024, policy=pol, eos_id=-1,
                 prefill_chunk=128)
    prompt = [3 + (i % 200) for i in range(512)]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    done = eng.run()
    assert len(done[0]) == 2
    assert eng.pool.free_pages == eng.pool.num_pages == 1


def test_paged_admits_long_context_bytebudget_refuses(rng):
    """ISSUE acceptance: at ~55% of one max_len=16k contiguous slot's
    bytes, ByteBudget cannot admit ANY request, while PagedAdmission
    admits and serves an 8k-token prompt at the same budget."""
    cfg = _softmax_cfg()
    max_len = 16384
    budget = per_slot_bytes(cfg, max_len) * 55 // 100
    with pytest.raises(ValueError, match="cannot admit"):
        ByteBudget(budget).resolve_slots(cfg, max_len)

    pol = PagedAdmission(budget, page_size=16, max_slots=1)
    assert pol.resolve_num_pages(cfg) * 16 >= 8192   # tokens the arena holds
    params = mdl.init_params(cfg, rng)
    eng = Engine(cfg, params, max_len=max_len, policy=pol, eos_id=-1,
                 prefill_chunk=2048)
    prompt = [3 + (i % 200) for i in range(8192)]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    done = eng.run()
    assert len(done[0]) == 2
    assert eng.request(0).state is RequestState.FINISHED
    assert eng.pool.free_pages == eng.pool.num_pages   # pages returned
