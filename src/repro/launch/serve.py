"""Serving launcher: batched generation through the request-lifecycle
engine (serve/engine.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --requests 8 --max-new 16

Admission defaults to fixed slots; --budget-mb switches to ByteBudget
admission (the slot count then resolves from the backend's exact
per-slot decode-cache bytes, so linear admits far more than softmax at
the same budget).  --page-size switches the softmax backend to the
paged-KV cache (docs/paged_kv.md): with --budget-mb the budget buys an
arena of KV pages (PagedAdmission — requests admit by the pages they
actually need), otherwise --num-pages (or a worst-case default) sizes
the arena directly.  --json-out writes the throughput record — and the
pages-in-use stats when paged — for CI artifacts.

Observability (docs/observability.md): --trace-out installs a
repro.obs ServeTracer and writes the Chrome trace-event JSON (open in
Perfetto, or `python -m repro.obs report trace.json`); --metrics-json
dumps the Counter/Gauge/Histogram registry snapshot.  Either flag also
embeds the latency summary (ttft / inter-token p50+p99, queue wait,
occupancy) in the result record.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from repro.tune.timer import now

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.kernels import ops as _ops
from repro.models import model as mdl
from repro.serve.cache import per_slot_bytes
from repro.serve.engine import Engine, Request
from repro.serve.paging import PagedAdmission
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import ByteBudget, FixedSlots


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--backend", default=None,
                    help="override cfg.attention_backend "
                         "(linear|gla|softmax)")
    ap.add_argument("--kernel", default=None,
                    help="kernel impl for the engine "
                         "(auto|xla|pallas|pallas_interpret); softmax + "
                         "pallas runs continuation prefill through the "
                         "flash kernel's q_offset path")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="ByteBudget admission instead of fixed slots "
                         "(with --page-size: PagedAdmission)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="paged cache: tokens per KV block (softmax) "
                         "or enable the paged recurrent-state arena "
                         "(gla: one state page per slot, the token "
                         "count is ignored)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged-KV arena pages incl. the reserved sink "
                         "(default: worst case for every slot)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill window (tokens)")
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--json-out", default=None,
                    help="also write the result record to this path")
    ap.add_argument("--trace-out", default=None,
                    help="trace requests through repro.obs and write "
                         "the Chrome trace-event JSON here (Perfetto-"
                         "loadable; `python -m repro.obs report` reads "
                         "the embedded per-request records)")
    ap.add_argument("--metrics-json", default=None,
                    help="write the repro.obs metrics registry "
                         "snapshot (counters/gauges/histograms) here")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel tile sizes from the tuning "
                         "cache (docs/autotuning.md) instead of the "
                         "static defaults")
    ap.add_argument("--tune-cache", default=None,
                    help="tuning cache path (implies --autotune; "
                         "default artifacts/tune_cache.json)")
    args = ap.parse_args()

    if args.num_pages is not None and args.page_size is None:
        ap.error("--num-pages requires --page-size (it sizes the paged "
                 "arena; without a page size the cache stays contiguous)")
    cfg = get_config(args.arch, smoke=True)
    if args.backend:
        cfg = dataclasses.replace(cfg, attention_backend=args.backend)
    tune_cache = None
    if args.autotune or args.tune_cache:
        from repro import tune as _tune
        from repro.configs.base import TuneCfg
        cfg = dataclasses.replace(cfg, tune=TuneCfg(
            enabled=True,
            cache_path=args.tune_cache or TuneCfg.cache_path))
        tune_cache = _tune.activate_from_cfg(cfg)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    page_kwargs = {}
    if args.budget_mb is not None and args.page_size is not None:
        policy = PagedAdmission(int(args.budget_mb * 1024 * 1024),
                                page_size=args.page_size,
                                max_slots=args.slots,
                                num_pages=args.num_pages)
    elif args.budget_mb is not None:
        policy = ByteBudget(int(args.budget_mb * 1024 * 1024))
    else:
        policy = FixedSlots(args.slots)
        page_kwargs = {"page_size": args.page_size,
                       "num_pages": args.num_pages}
    tracer = None
    if args.trace_out or args.metrics_json:
        from repro.obs import ServeTracer
        tracer = ServeTracer()
    engine = Engine(cfg, params, max_len=args.max_len, policy=policy,
                    prefill_chunk=args.prefill_chunk,
                    kernel_backend=args.kernel, tracer=tracer,
                    **page_kwargs)

    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(3, cfg.vocab_size,
                              size=args.prompt_len).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new, sampling=sp))
    t0 = now()
    done, peak_pages = {}, 0
    for out in engine.stream():
        if engine.pool is not None:
            peak_pages = max(peak_pages, engine.pool.pages_in_use)
        if out.finished:
            done[out.rid] = engine.request(out.rid).generated
    dt = now() - t0
    total_tokens = sum(len(v) for v in done.values())
    record = {
        "arch": args.arch,
        "backend": cfg.attention_backend if cfg.mixer == "attention"
        else cfg.mixer,
        "kernel": _ops.default_backend()
        if engine.cfg.la.backend == "auto" else engine.cfg.la.backend,
        "policy": type(engine.policy).__name__,
        "slots": engine.num_slots,
        "per_slot_bytes": per_slot_bytes(cfg, args.max_len),
        "requests": len(done),
        "generated_tokens": total_tokens,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_tokens / dt, 1),
        "autotune": {"enabled": tune_cache is not None,
                     "cache_path": cfg.tune.cache_path if cfg.tune else None,
                     "cache_entries": len(tune_cache) if tune_cache else 0},
    }
    if engine.pool is not None:
        record["paging"] = dict(engine.page_stats(),
                                peak_pages_in_use=peak_pages)
    if tracer is not None:
        record["latency"] = tracer.summary()
        if args.trace_out:
            tracer.export_chrome_trace(args.trace_out)
        if args.metrics_json:
            with open(args.metrics_json, "w") as f:
                json.dump(tracer.metrics.to_json(), f, indent=2)
    print(json.dumps(record))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=2)


if __name__ == "__main__":
    main()
