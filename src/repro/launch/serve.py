"""Serving launcher: batched generation through the continuous-batching
engine (serve/engine.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import model as mdl
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_slots=args.slots, max_len=512)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(3, cfg.vocab_size,
                              size=args.prompt_len).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(json.dumps({
        "requests": len(done), "generated_tokens": total_tokens,
        "wall_s": round(dt, 3),
        "tokens_per_s": round(total_tokens / dt, 1)}))


if __name__ == "__main__":
    main()
