"""Production meshes.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).

Single pod: 256 chips as (16, 16) = ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) = ("pod", "data", "model");
the "pod" axis rides DCN, "data"/"model" ride ICI.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()[:need]
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever this host has (tests / examples): (1, n_devices)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
