import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count at first init.
# The 512 placeholder host devices exist ONLY for this dry-run; smoke
# tests and benches see the real single CPU device.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the full architecture config and ShapeDtypeStruct inputs
     (no real allocation anywhere — params via jax.eval_shape);
  2. jits the right step (train_step / prefill_step / serve_step) with
     the production shardings from distributed/;
  3. .lower().compile() against the 256-chip single-pod mesh and the
     512-chip 2-pod mesh — success proves the distribution config is
     coherent (sharding propagation, collectives, memory);
  4. records memory_analysis / cost_analysis / per-chip collective bytes
     into artifacts/dryrun/*.json for the §Roofline tables.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
from repro.tune.timer import now
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.hlo import total_costs
from repro.analysis.roofline import Roofline, model_flops_for, save_artifact
from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import ARCHS, get_config, get_shape, input_specs
from repro.distributed.act_sharding import use_activation_policy
from repro.distributed.sharding import batch_shardings, cache_shardings, \
    param_shardings
from repro.distributed.zero import opt_state_shardings
from repro.launch.mesh import make_production_mesh
from repro.mixers import get_backend
from repro.models import model as mdl
from repro.optim import adamw
from repro.train.step import build_prefill_step, build_serve_step, \
    build_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")


def _with_shardings(struct, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct, shardings)


def _microbatch_for(cfg, shape, chips, budget_bytes: float = 6e9) -> int:
    """Gradient-accumulation factor so the remat stash (one bf16 block
    input per layer per microbatch token) fits the per-chip budget."""
    dp = max(chips // 16, 1)  # data(+pod) degree on the production meshes
    per_dev_tokens = shape.global_batch * shape.seq_len / dp
    layers = cfg.num_layers + cfg.encoder_layers
    stash = per_dev_tokens * cfg.d_model * 2 * layers
    mb = 1
    while stash / mb > budget_bytes and mb < shape.global_batch and \
            shape.global_batch % (mb * 2) == 0:
        mb *= 2
    return 0 if mb == 1 else mb


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               smoke: bool = False, cfg=None, donate: bool = True):
    """Lower+compile one cell.  Returns (compiled, meta dict)."""
    cfg = cfg or get_config(arch, smoke=smoke)
    get_backend(cfg)  # registry-resolution validation before any compile
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    specs, kind = input_specs(cfg, shape)

    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_s = jax.eval_shape(partial(mdl.init_params, cfg), key_s)
    params_sh = param_shardings(params_s, mesh)
    params_in = _with_shardings(params_s, params_sh)

    with use_activation_policy(mesh):
        if kind == "train":
            tc = TrainConfig(microbatch=_microbatch_for(cfg, shape, chips))
            step = build_train_step(cfg, tc)
            opt_s = jax.eval_shape(adamw.init, params_s)
            opt_sh = opt_state_shardings(opt_s, mesh)
            opt_in = _with_shardings(opt_s, opt_sh)
            batch_in = _with_shardings(specs, batch_shardings(specs, mesh))
            step_idx = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(step,
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_in, opt_in, batch_in, step_idx)
        elif kind == "prefill":
            # chunked prefill for long prompts: windowed state-carrying
            # passes cap peak activation memory (exact for LA/SSD)
            window = 8192 if shape.seq_len > 8192 else None
            fn = build_prefill_step(cfg, window=window)
            batch_in = _with_shardings(specs, batch_shardings(specs, mesh))
            lowered = jax.jit(fn).lower(params_in, batch_in)
        else:  # decode
            fn = build_serve_step(cfg)
            cache_s = specs["cache"]
            cache_in = _with_shardings(cache_s,
                                       cache_shardings(cache_s, mesh))
            tok_in = _with_shardings(
                {"t": specs["tokens"]},
                batch_shardings({"t": specs["tokens"]}, mesh))["t"]
            jitted = jax.jit(fn, donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_in, cache_in, tok_in)

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()  # raw (loop bodies counted once)
    struct = total_costs(compiled.as_text())  # trip-count-corrected
    mesh_name = "2x16x16" if multi_pod else "16x16"

    mem_stats = None
    if mem is not None:
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
        mem_stats["total_per_device"] = (
            mem_stats["argument_bytes"] + mem_stats["output_bytes"]
            + mem_stats["temp_bytes"] - mem_stats["alias_bytes"])

    r = Roofline(
        arch=cfg.name if not smoke else arch,
        shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=float(struct["flops"]),
        bytes_per_device=float(struct["bytes"]),
        collective_bytes=float(struct["collective_bytes"]),
        model_flops=model_flops_for(cfg, shape),
        memory_stats=mem_stats,
        collective_detail={"by_kind": struct["by_kind"],
                           "raw_hlo_flops": float(cost.get("flops", 0.0)),
                           "raw_hlo_bytes": float(
                               cost.get("bytes accessed", 0.0))},
    ).finalize()
    return compiled, r


def run_cell(arch, shape_name, multi_pod, smoke=False, verbose=True):
    t0 = now()
    compiled, r = lower_cell(arch, shape_name, multi_pod=multi_pod,
                             smoke=smoke)
    dt = now() - t0
    if verbose:
        print(f"[OK] {arch} x {shape_name} x {r.mesh}  "
              f"({dt:.1f}s compile)")
        print(f"     memory_analysis: {compiled.memory_analysis()}")
        print(f"     structural cost: flops/dev={r.flops_per_device:.3e} "
              f"bytes/dev={r.bytes_per_device:.3e} (raw cost_analysis "
              f"flops={r.collective_detail['raw_hlo_flops']:.3e})")
        print(f"     collectives/chip: {r.collective_bytes:.3e} B "
              f"{r.collective_detail['by_kind']}")
        print(f"     roofline: T_comp={r.t_compute:.3e}s "
              f"T_mem={r.t_memory:.3e}s T_coll={r.t_collective:.3e}s "
              f"dominant={r.dominant} useful={r.usefulness:.3f}")
    fn = save_artifact(r, ARTIFACT_DIR)
    del compiled
    return r, fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (CI sanity)")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                try:
                    run_cell(arch, shape_name, multi_pod, smoke=args.smoke)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, multi_pod, str(e)))
                    print(f"[FAIL] {arch} x {shape_name} x "
                          f"{'2x16x16' if multi_pod else '16x16'}: {e}")
                    if not args.continue_on_error:
                        traceback.print_exc()
                        raise
    print(json.dumps({"failures": failures}, indent=1))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
