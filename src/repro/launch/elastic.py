"""Elastic re-meshing: rebuild the mesh from the live device set and
reshard a checkpoint onto it.

At 1000+ nodes, hardware failures shrink the healthy device set; an
elastic job must (1) decide a new mesh shape from what is alive,
(2) reload the last checkpoint with shardings for the NEW mesh (the
checkpoint store device_puts each leaf with any sharding), and
(3) rescale the data-parallel batch.  This module implements the
decision logic; the Trainer's straggler monitor triggers it.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import AxisType

from repro.checkpoint import store
from repro.distributed.sharding import param_shardings


def choose_mesh_shape(n_devices: int, model_parallel: int = 16):
    """Largest (data, model) grid that fits the live device count.

    Keeps the model axis fixed (param layout depends on it — a smaller
    model axis would not fit the shards) and shrinks the data axis to
    the largest divisor that fits; leftover devices idle until the next
    re-mesh window.
    """
    if n_devices < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{n_devices} devices")
    data = n_devices // model_parallel
    # power-of-two data axis keeps batch divisibility stable
    data = 2 ** int(math.log2(data))
    return (data, model_parallel)


def remesh(devices=None, model_parallel: int = 16):
    devices = devices if devices is not None else jax.devices()
    shape = choose_mesh_shape(len(devices), model_parallel)
    need = shape[0] * shape[1]
    return jax.make_mesh(shape, ("data", "model"),
                         devices=devices[:need],
                         axis_types=(AxisType.Auto,) * 2)


def restore_on_mesh(ckpt_dir: str, tree_like, mesh, step=None):
    """Reload a checkpoint resharded for a (possibly different) mesh."""
    shardings = param_shardings(tree_like, mesh)
    return store.restore(ckpt_dir, tree_like, step=step,
                         shardings=shardings)


def rescale_batch(global_batch: int, old_mesh, new_mesh) -> int:
    """Keep per-device batch constant across a re-mesh (linear scaling
    rule applies to the LR schedule — the Trainer logs the change)."""
    def dp(mesh):
        return math.prod(mesh.shape[a] for a in ("pod", "data")
                         if a in mesh.shape)
    per_device = global_batch // dp(old_mesh)
    return per_device * dp(new_mesh)
