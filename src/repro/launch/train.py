"""Training launcher: --arch / --shape / --steps CLI.

On this CPU container it runs reduced (smoke) configs end-to-end —
data pipeline -> jitted train step -> checkpoints — exercising the same
code path the production mesh uses (launch/dryrun.py proves the full
configs lower on 256/512 chips).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLM
from repro.mixers import get_backend
from repro.models import model as mdl
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pythia-1.4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--backend", default=None,
                    help="linear (paper) | softmax (baseline)")
    ap.add_argument("--full", action="store_true",
                    help="full (non-smoke) config — needs real accelerators")
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve kernel tile sizes from the tuning "
                         "cache (docs/autotuning.md) instead of the "
                         "static defaults")
    ap.add_argument("--tune-cache", default=None,
                    help="tuning cache path (implies --autotune; "
                         "default artifacts/tune_cache.json)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    if args.backend:
        cfg = dataclasses.replace(cfg, attention_backend=args.backend)
    tune_cache = None
    if args.autotune or args.tune_cache:
        from repro import tune as _tune
        from repro.configs.base import TuneCfg
        cfg = dataclasses.replace(cfg, tune=TuneCfg(
            enabled=True,
            cache_path=args.tune_cache or TuneCfg.cache_path))
        tune_cache = _tune.activate_from_cfg(cfg)
    get_backend(cfg)  # fail fast on a bad --backend, naming the valid ones
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     checkpoint_every=max(args.steps // 2, 1),
                     checkpoint_dir=args.checkpoint_dir)

    params = mdl.init_params(cfg, jax.random.PRNGKey(tc.seed))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=tc.seed)
    trainer = Trainer(cfg, tc, params, data)
    if args.resume:
        trainer.try_restore()
    history = trainer.run(args.steps - trainer.step_idx)
    print(json.dumps({"first_loss": history[0]["loss"],
                      "last_loss": history[-1]["loss"],
                      "steps": len(history),
                      "stragglers": trainer.monitor.flagged,
                      "autotune": {
                          "enabled": tune_cache is not None,
                          "cache_entries": len(tune_cache)
                          if tune_cache else 0}}))


if __name__ == "__main__":
    main()
