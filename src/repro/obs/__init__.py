"""repro.obs — request-lifecycle tracing + serving metrics.

The serving-side observability tier (docs/observability.md), the
counterpart of repro.tune's kernel-level rooflines:

  obs.events    Tracer protocol (nil-by-default engine hooks),
                ServeTracer recorder, RequestRecord derived spans,
                Chrome trace-event export (Perfetto-loadable)
  obs.metrics   Counter/Gauge/Histogram registry with fixed log-spaced
                latency buckets; JSON + Prometheus text exposition;
                the ONE home for percentile math in the serving stack
  python -m repro.obs report trace.json
                per-request latency table from an exported trace

Wiring: `Engine(cfg, params, tracer=ServeTracer())`, or
`launch/serve.py --trace-out trace.json --metrics-json metrics.json`.
"""
from repro.obs.events import RequestRecord, ServeTracer, Tracer
from repro.obs.metrics import (BUCKET_RATIO, LATENCY_BUCKETS, Counter,
                               Gauge, Histogram, MetricsRegistry,
                               log_buckets, percentiles)

__all__ = [
    "BUCKET_RATIO", "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS",
    "MetricsRegistry", "RequestRecord", "ServeTracer", "Tracer",
    "log_buckets", "percentiles",
]
