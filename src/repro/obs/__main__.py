"""CLI: render the per-request latency table from an exported trace.

    python -m repro.obs report trace.json [--json]

Reads the `repro_records` block a ServeTracer embeds alongside the
Chrome `traceEvents` (the trace stays Perfetto-loadable; the records
carry the derived quantities so the table needs no span re-assembly)
and prints one row per request — queue wait, ttft, inter-token p50/p99,
prefill vs decode split, finish reason — plus the run summary
BENCH_serve.json cells are built from.  `--json` dumps the records +
summary as JSON instead of the table.
"""
from __future__ import annotations

import argparse
import json
import sys

COLUMNS = [
    ("rid", "rid", "d"),
    ("prompt", "prompt_len", "d"),
    ("toks", "tokens", "d"),
    ("queue_ms", "queue_wait_s", "ms"),
    ("ttft_ms", "ttft_s", "ms"),
    ("itl_p50_ms", "inter_token_p50_s", "ms"),
    ("itl_p99_ms", "inter_token_p99_s", "ms"),
    ("prefill_ms", "prefill_s", "ms"),
    ("decode_ms", "decode_s", "ms"),
    ("total_ms", "total_s", "ms"),
    ("reason", "finish_reason", "s"),
]


def _fmt(value, kind: str) -> str:
    if value is None:
        return "-"
    if kind == "ms":
        return f"{value * 1e3:.2f}"
    if kind == "d":
        return f"{value:d}"
    return str(value)


def format_table(records: list) -> str:
    rows = [[head for head, _, _ in COLUMNS]]
    for rec in records:
        rows.append([_fmt(rec.get(key), kind)
                     for _, key, kind in COLUMNS])
    widths = [max(len(r[i]) for r in rows) for i in range(len(COLUMNS))]
    return "\n".join(
        "  ".join(cell.rjust(w) for cell, w in zip(row, widths))
        for row in rows)


def report(path: str, as_json: bool = False) -> int:
    with open(path) as f:
        doc = json.load(f)
    records = doc.get("repro_records")
    if records is None:
        print(f"{path}: no repro_records block — was this trace "
              f"exported by repro.obs.ServeTracer.export_chrome_trace?",
              file=sys.stderr)
        return 1
    summary = doc.get("repro_summary", {})
    if as_json:
        print(json.dumps({"records": records, "summary": summary},
                         indent=1))
        return 0
    print(format_table(records))
    if summary:
        occ = summary.get("occupancy")
        print(f"\n{summary.get('finished')}/{summary.get('requests')} "
              f"requests finished, {summary.get('tokens')} tokens over "
              f"{summary.get('steps')} engine steps"
              + ("" if occ is None else f", mean occupancy {occ:.2f}"))
        for key in ("ttft_ms", "inter_token_ms", "queue_wait_ms"):
            ps = summary.get(key) or {}
            print(f"  {key}: p50={ps.get('p50')} p99={ps.get('p99')}")
    unclosed = [r["rid"] for r in records if not r.get("closed")]
    if unclosed:
        print(f"  WARNING: unfinished request span(s): {unclosed}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="request-lifecycle trace reporting")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report",
                         help="per-request latency table from a trace")
    rep.add_argument("trace", help="trace.json written by --trace-out "
                                   "or ServeTracer.export_chrome_trace")
    rep.add_argument("--json", action="store_true",
                     help="emit records + summary as JSON, not a table")
    args = ap.parse_args(argv)
    return report(args.trace, as_json=args.json)


if __name__ == "__main__":
    sys.exit(main())
