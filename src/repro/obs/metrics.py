"""Process-local serving metrics: Counter / Gauge / Histogram + exposition.

The request-lifecycle tier of the repo's observability story
(docs/observability.md).  PR 6's `repro.tune` owns kernel-level
numbers (roofline fractions in every BENCH cell); this registry owns
the serving-side signals the ROADMAP's scheduler work must report
against: ttft, inter-token latency, queue wait, occupancy.

Contracts:

  * Histograms use FIXED log-spaced bucket bounds shared by every
    instrument, so p50/p90/p99 are derivable from any SNAPSHOT (a
    scraped Prometheus exposition, a metrics JSON artifact) without the
    raw observations — two snapshots are always mergeable bucket-wise.
  * This module is the ONE home for percentile math in the serving
    stack: `Histogram.percentile` (bucketed) and `percentiles` (exact,
    for small in-memory sample lists).  repro.check lint rule
    REPRO-L004 rejects ad-hoc `np.percentile` / `sorted(xs)[int(p*n)]`
    arithmetic anywhere else under `serve/` or `obs/`, the same way
    REPRO-L001 keeps wall-clock reads inside `tune/timer.py`.
  * No clocks here: values are observed in seconds by callers that
    stamp via `repro.tune.timer.now()` (obs/events.py).

Exposition: `MetricsRegistry.to_json()` for artifacts and
`MetricsRegistry.prometheus_text()` (text exposition format 0.0.4) for
scrapers; both are pure snapshots of host-side state.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, Iterable, List, Optional, Sequence, Union


def log_buckets(lo: float = 1e-5, hi: float = 100.0,
                per_decade: int = 8) -> List[float]:
    """Log-spaced bucket upper bounds covering [lo, hi] — default 10 us
    to 100 s at 8 buckets per decade (adjacent bounds differ by
    10^(1/8) ~= 1.33x, so a bucketed p99 is within ~33% of exact)."""
    if lo <= 0 or hi <= lo or per_decade < 1:
        raise ValueError(f"bad bucket spec lo={lo} hi={hi} "
                         f"per_decade={per_decade}")
    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * 10 ** (i / per_decade) for i in range(n + 1)]


#: the repo-wide latency bucket bounds (seconds) — every latency
#: histogram shares them so snapshots are mergeable across engines
LATENCY_BUCKETS = tuple(log_buckets())

#: ratio between adjacent LATENCY_BUCKETS bounds — the worst-case
#: multiplicative error of a bucketed percentile (tests pin this)
BUCKET_RATIO = 10 ** (1 / 8)


def percentiles(values: Sequence[float],
                ps: Iterable[float]) -> Dict[float, Optional[float]]:
    """Exact order-statistic percentiles (inverted-CDF: the smallest
    observation x with CDF(x) >= p/100, i.e. sorted[ceil(p/100*n)-1]).

    The serving stack's one sanctioned exact implementation — bench
    summaries and the `repro.obs report` table both call this, so a p99
    in BENCH_serve.json means the same thing as one in the CLI table.
    Returns {p: None} for an empty sample.
    """
    ps = list(ps)
    if not values:
        return {p: None for p in ps}
    xs = sorted(values)
    n = len(xs)
    out = {}
    for p in ps:
        if not 0 <= p <= 100:
            raise ValueError(f"percentile {p} outside [0, 100]")
        rank = max(1, math.ceil(p / 100.0 * n))
        out[p] = xs[min(rank, n) - 1]
    return out


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------

class Counter:
    """Monotonically increasing count (events, tokens, rejections)."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        self.value += n

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Last-written level (slots active, pages in use, queue depth)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value: Optional[float] = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value}


class Histogram:
    """Fixed-bound bucketed distribution; percentiles from the snapshot.

    `counts[i]` holds observations v with bounds[i-1] < v <= bounds[i]
    (bisect_left on the upper bounds); the final slot is the +Inf
    overflow.  `percentile(p)` returns the UPPER bound of the bucket
    holding the p-th-percentile observation — an upper estimate within
    one bucket ratio of the exact value (tests pin both sides against a
    numpy oracle).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        bounds = list(buckets)
        if bounds != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram {name}: bucket bounds must be "
                             f"strictly increasing")
        self.name = name
        self.help = help
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = +Inf overflow
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += 1
        self.sum += v

    def percentile(self, p: float) -> Optional[float]:
        """Upper bucket bound covering the p-th percentile observation
        (inverted-CDF rank, like `percentiles`); None when empty; +inf
        when the rank lands in the overflow bucket."""
        if not 0 <= p <= 100:
            raise ValueError(f"percentile {p} outside [0, 100]")
        if self.total == 0:
            return None
        rank = max(1, math.ceil(p / 100.0 * self.total))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[i] if i < len(self.bounds) else math.inf
        return math.inf  # unreachable: cum ends at self.total >= rank

    def snapshot(self) -> dict:
        nonzero = [[self.bounds[i] if i < len(self.bounds) else None, c]
                   for i, c in enumerate(self.counts) if c]
        return {"kind": self.kind, "count": self.total, "sum": self.sum,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99),
                "buckets": nonzero}   # [upper_bound_or_None(+Inf), count]


Metric = Union[Counter, Gauge, Histogram]


# ---------------------------------------------------------------------------
# Registry + exposition
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    Process-local and jax-free: instruments are updated from host-side
    engine code between jitted steps, never inside a traced function.
    """

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, help, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    def to_json(self) -> dict:
        """The metrics artifact (launch/serve.py --metrics-json)."""
        return {"version": 1, "metrics": self.snapshot()}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                cum = 0
                for i, c in enumerate(m.counts[:-1]):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{m.bounds[i]:.9g}"}}'
                                 f" {cum}")
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.total}')
                lines.append(f"{name}_sum {m.sum:.9g}")
                lines.append(f"{name}_count {m.total}")
            else:
                v = m.value
                lines.append(f"{name} "
                             f"{'NaN' if v is None else format(v, '.9g')}")
        return "\n".join(lines) + "\n"
