"""Per-request span/event recorder + Chrome trace-event export.

The serving engine reports a single end-of-run tokens/s number; this
module records the request LIFECYCLE behind it —

    SUBMIT -> QUEUED -> ADMITTED -> PREFILL[window spans]
           -> DECODE[per-token ticks] -> FINISHED

— so the paper's structural claim (an O(D^2) recurrent state makes
decode latency flat in context length and admission nearly free) is
measurable in wall-clock terms: queue wait, time-to-first-token,
inter-token deltas, prefill vs decode split, per request.

Two layers:

  Tracer       the nil-by-default instrumentation protocol.  Every hook
               is a no-op; serve/engine.py, serve/scheduler.py and
               serve/paging.py call hooks only when a tracer is
               installed (`if tracer is not None`), so the disabled
               engine path costs one host-side None check per event and
               touches no jitted code — engine output with tracing on
               is token-identical to tracing off (pinned by
               tests/test_obs.py).
  ServeTracer  the real recorder: builds one RequestRecord span tree
               per rid, feeds a MetricsRegistry (obs/metrics.py), and
               exports a Chrome trace-event JSON loadable in Perfetto
               (one track per engine slot, one per request).

Timestamps come EXCLUSIVELY from `repro.tune.timer.now()` — the repo's
one monotonic clock (repro.check REPRO-L001/L004 keep it that way).
Span ends are stamped on hook receipt; span starts (`t0`) are stamped
by the caller via `Tracer.clock()` so a span never includes the hook
dispatch itself.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry, percentiles
from repro.tune import timer


class Tracer:
    """Nil instrumentation protocol — subclass and override.

    Hook order per request: request_submitted, request_queued, zero or
    more admission_blocked, request_admitted, one prefill_window per
    prompt chunk, one token_emitted per generated token (the first tick
    defines ttft), request_finished.  A preempted request additionally
    sees request_preempted (policy: "snapshot" | "page_keep" |
    "recompute", docs/serving.md) followed later by request_resumed —
    possibly several such pairs.  Engine-level: engine_step once
    per Engine.step(); pool-level: pages_changed / cow_fork /
    sink_repoint.  request_rejected replaces the whole tree for
    requests refused at submit.
    """

    @staticmethod
    def clock() -> float:
        """Span-start stamp for callers (tune.timer.now passthrough)."""
        return timer.now()

    # -- request lifecycle --------------------------------------------
    def request_submitted(self, rid: int, prompt_len: int,
                          max_new: int) -> None:
        pass

    def request_queued(self, rid: int) -> None:
        pass

    def request_rejected(self, rid: int, reason: str) -> None:
        pass

    def admission_blocked(self, rid: int, reason: str) -> None:
        pass

    def request_admitted(self, rid: int, slot: int) -> None:
        pass

    def prefill_window(self, rid: int, slot: int, tokens: int,
                       t0: float) -> None:
        pass

    def token_emitted(self, rid: int, slot: int) -> None:
        pass

    def request_preempted(self, rid: int, slot: int,
                          policy: str) -> None:
        pass

    def request_resumed(self, rid: int, slot: int, policy: str) -> None:
        pass

    def request_finished(self, rid: int, reason: str,
                         t: Optional[float] = None) -> None:
        pass

    # -- engine / pool level ------------------------------------------
    def engine_step(self, t0: float, active: int, slots: int,
                    queued: int) -> None:
        pass

    def pages_changed(self, in_use: int, free: int) -> None:
        pass

    def cow_fork(self) -> None:
        pass

    def sink_repoint(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Per-request derived record
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RequestRecord:
    """One request's span tree, with the derived latency quantities the
    scheduler roadmap items are judged on."""

    rid: int
    prompt_len: int = 0
    max_new: int = 0
    submit_t: Optional[float] = None
    queued_t: Optional[float] = None
    admitted_t: Optional[float] = None
    slot: Optional[int] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    finish_reason: Optional[str] = None
    blocked: int = 0                      # admission_blocked events seen
    token_ts: List[float] = dataclasses.field(default_factory=list)
    # (t0, t1, tokens) per prefill window, in execution order
    prefill_windows: List[tuple] = dataclasses.field(default_factory=list)
    # (t, slot, policy) per eviction / per resume, in order
    preempt_events: List[tuple] = dataclasses.field(default_factory=list)
    resume_events: List[tuple] = dataclasses.field(default_factory=list)

    # -- derived -------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self.finish_t is not None

    @property
    def tokens(self) -> int:
        return len(self.token_ts)

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.admitted_t is None or self.queued_t is None:
            return None
        return self.admitted_t - self.queued_t

    @property
    def ttft_s(self) -> Optional[float]:
        """First token minus submit — the user-visible first-byte wait
        (queue wait + prefill + first sample)."""
        start = self.submit_t if self.submit_t is not None else self.queued_t
        if self.first_token_t is None or start is None:
            return None
        return self.first_token_t - start

    @property
    def inter_token_s(self) -> List[float]:
        return [b - a for a, b in zip(self.token_ts, self.token_ts[1:])]

    @property
    def preemptions(self) -> int:
        return len(self.preempt_events)

    @property
    def preempted_s(self) -> Optional[float]:
        """Total time spent evicted (sum of preempt -> resume spans);
        None if the request was never preempted."""
        if not self.preempt_events:
            return None
        total = 0.0
        for (t0, _, _), (t1, _, _) in zip(self.preempt_events,
                                          self.resume_events):
            total += t1 - t0
        return total

    @property
    def prefill_s(self) -> Optional[float]:
        if not self.prefill_windows:
            return None
        return sum(t1 - t0 for t0, t1, _ in self.prefill_windows)

    @property
    def decode_s(self) -> Optional[float]:
        if self.finish_t is None or self.first_token_t is None:
            return None
        return self.finish_t - self.first_token_t

    @property
    def total_s(self) -> Optional[float]:
        start = self.submit_t if self.submit_t is not None else self.queued_t
        if self.finish_t is None or start is None:
            return None
        return self.finish_t - start

    def to_json(self) -> dict:
        itl = percentiles(self.inter_token_s, (50, 99))
        return {
            "rid": self.rid, "prompt_len": self.prompt_len,
            "max_new": self.max_new, "slot": self.slot,
            "tokens": self.tokens, "finish_reason": self.finish_reason,
            "blocked": self.blocked, "closed": self.closed,
            "submit_t": self.submit_t, "finish_t": self.finish_t,
            "queue_wait_s": self.queue_wait_s, "ttft_s": self.ttft_s,
            "prefill_s": self.prefill_s, "decode_s": self.decode_s,
            "total_s": self.total_s,
            "prefill_windows": len(self.prefill_windows),
            "preemptions": self.preemptions,
            "preempted_s": self.preempted_s,
            "inter_token_p50_s": itl[50], "inter_token_p99_s": itl[99],
        }


# ---------------------------------------------------------------------------
# The real recorder
# ---------------------------------------------------------------------------

def _ms(ps: Dict[float, Optional[float]]) -> Dict[str, Optional[float]]:
    return {f"p{int(p)}": None if v is None else round(v * 1e3, 4)
            for p, v in ps.items()}


class ServeTracer(Tracer):
    """Records every event, derives RequestRecords, feeds metrics."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._reqs: Dict[int, RequestRecord] = {}
        self._steps: List[tuple] = []   # (t0, t1, active, slots, queued)
        self._t0: Optional[float] = None
        m = self.metrics
        self._c_submitted = m.counter(
            "serve_requests_submitted_total", "requests submitted")
        self._c_accept = m.counter(
            "serve_admission_accept_total", "requests admitted to a slot")
        self._c_block = m.counter(
            "serve_admission_block_total",
            "admission attempts blocked (head of FIFO queue waiting on "
            "slots or pages)")
        self._c_reject = m.counter(
            "serve_admission_reject_total",
            "requests refused at submit (can never be admitted)")
        self._c_finished = m.counter(
            "serve_requests_finished_total", "requests finished")
        self._c_tokens = m.counter(
            "serve_tokens_total", "tokens emitted")
        self._c_forks = m.counter(
            "serve_page_cow_forks_total", "copy-on-write page-table forks")
        self._c_sink = m.counter(
            "serve_sink_repoints_total",
            "freed slots re-pointed at the arena sink page")
        self._c_preempt = m.counter(
            "serve_preemptions_total",
            "requests evicted mid-decode for higher-priority work")
        self._c_resume = m.counter(
            "serve_resumes_total",
            "preempted requests re-admitted (page swap, snapshot "
            "restore, or drop-and-recompute)")
        self._g_active = m.gauge(
            "serve_slots_active", "slots decoding this step")
        self._g_occ = m.gauge(
            "serve_slot_occupancy",
            "batch utilization: active slots / total slots (padded "
            "decode rows are wasted compute)")
        self._g_queue = m.gauge(
            "serve_queue_depth", "requests waiting in the FIFO queue")
        self._g_pages_used = m.gauge(
            "serve_pages_in_use", "arena pages allocated")
        self._g_pages_free = m.gauge(
            "serve_pages_free", "arena pages on the free list")
        self._h_ttft = m.histogram(
            "serve_ttft_seconds", "submit -> first token")
        self._h_itl = m.histogram(
            "serve_inter_token_seconds", "delta between consecutive "
            "tokens of one request")
        self._h_queue = m.histogram(
            "serve_queue_wait_seconds", "queued -> admitted")
        self._h_prefill = m.histogram(
            "serve_prefill_window_seconds", "one chunked-prefill window")
        self._h_step = m.histogram(
            "serve_step_seconds", "one Engine.step() iteration")
        self._h_e2e = m.histogram(
            "serve_e2e_seconds", "submit -> finished")
        self._h_preempted = m.histogram(
            "serve_preempted_seconds", "one preempt -> resume span")

    # -- internals -----------------------------------------------------
    def _rec(self, rid: int) -> RequestRecord:
        rec = self._reqs.get(rid)
        if rec is None:
            rec = self._reqs[rid] = RequestRecord(rid=rid)
        return rec

    def _stamp(self, t: Optional[float] = None) -> float:
        t = timer.now() if t is None else t
        if self._t0 is None or t < self._t0:
            self._t0 = t
        return t

    # -- Tracer hooks --------------------------------------------------
    def request_submitted(self, rid, prompt_len, max_new):
        rec = self._rec(rid)
        rec.submit_t = self._stamp()
        rec.prompt_len = prompt_len
        rec.max_new = max_new
        self._c_submitted.inc()

    def request_queued(self, rid):
        self._rec(rid).queued_t = self._stamp()

    def request_rejected(self, rid, reason):
        rec = self._rec(rid)
        rec.finish_t = self._stamp()
        rec.finish_reason = f"rejected:{reason}"
        self._c_reject.inc()

    def admission_blocked(self, rid, reason):
        self._rec(rid).blocked += 1
        self._c_block.inc()

    def request_admitted(self, rid, slot):
        rec = self._rec(rid)
        rec.admitted_t = self._stamp()
        rec.slot = slot
        self._c_accept.inc()
        if rec.queued_t is not None:
            self._h_queue.observe(rec.admitted_t - rec.queued_t)

    def prefill_window(self, rid, slot, tokens, t0):
        t1 = self._stamp()
        self._rec(rid).prefill_windows.append((t0, t1, tokens))
        self._h_prefill.observe(t1 - t0)

    def token_emitted(self, rid, slot):
        rec = self._rec(rid)
        t = self._stamp()
        if not rec.token_ts:
            rec.first_token_t = t
            start = rec.submit_t if rec.submit_t is not None \
                else rec.queued_t
            if start is not None:
                self._h_ttft.observe(t - start)
        else:
            self._h_itl.observe(t - rec.token_ts[-1])
        rec.token_ts.append(t)
        self._c_tokens.inc()

    def request_preempted(self, rid, slot, policy):
        self._rec(rid).preempt_events.append(
            (self._stamp(), slot, policy))
        self._c_preempt.inc()

    def request_resumed(self, rid, slot, policy):
        rec = self._rec(rid)
        t = self._stamp()
        rec.resume_events.append((t, slot, policy))
        self._c_resume.inc()
        if rec.preempt_events:
            self._h_preempted.observe(t - rec.preempt_events[-1][0])

    def request_finished(self, rid, reason, t=None):
        rec = self._rec(rid)
        rec.finish_t = self._stamp(t)
        rec.finish_reason = reason
        self._c_finished.inc()
        if rec.total_s is not None:
            self._h_e2e.observe(rec.total_s)

    def engine_step(self, t0, active, slots, queued):
        t1 = self._stamp()
        self._steps.append((t0, t1, active, slots, queued))
        self._g_active.set(active)
        self._g_occ.set(active / slots if slots else 0.0)
        self._g_queue.set(queued)
        self._h_step.observe(t1 - t0)

    def pages_changed(self, in_use, free):
        self._g_pages_used.set(in_use)
        self._g_pages_free.set(free)

    def cow_fork(self):
        self._c_forks.inc()

    def sink_repoint(self):
        self._c_sink.inc()

    def reset(self) -> None:
        """Drop every record, step span and metric sample, keeping the
        tracer OBJECT (the engine, scheduler and page pool all hold a
        reference to it).  Lets a benchmark run a jit-warmup workload
        through the instrumented engine and then measure from a clean
        slate — without this, the one-time compile spikes dominate any
        latency percentile the cell reports."""
        self.__init__()

    # -- derived views -------------------------------------------------
    def records(self) -> List[RequestRecord]:
        return [self._reqs[rid] for rid in sorted(self._reqs)]

    def occupancy(self) -> Optional[float]:
        """Mean active-slots / total-slots over the engine steps seen —
        the batch-utilization number BENCH_serve.json reports."""
        if not self._steps:
            return None
        return sum(a / s for _, _, a, s, _ in self._steps if s) \
            / len(self._steps)

    def summary(self) -> dict:
        """The BENCH_serve.json cell body: exact p50/p99 over the raw
        per-request samples (obs.metrics.percentiles), plus occupancy."""
        recs = self.records()
        ttfts = [r.ttft_s for r in recs if r.ttft_s is not None]
        waits = [r.queue_wait_s for r in recs
                 if r.queue_wait_s is not None]
        itl = [d for r in recs for d in r.inter_token_s]
        occ = self.occupancy()
        return {
            "requests": len(recs),
            "finished": sum(1 for r in recs if r.closed),
            "tokens": sum(r.tokens for r in recs),
            "ttft_ms": _ms(percentiles(ttfts, (50, 99))),
            "inter_token_ms": _ms(percentiles(itl, (50, 99))),
            "queue_wait_ms": _ms(percentiles(waits, (50, 99))),
            "occupancy": None if occ is None else round(occ, 4),
            "preemptions": sum(r.preemptions for r in recs),
            "steps": len(self._steps),
        }

    # -- Chrome trace export -------------------------------------------
    def export_chrome_trace(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (chrome://tracing / Perfetto).

        Tracks: pid 0 "engine" (step spans), pid 1 "slots" (one tid per
        slot: prefill windows + token instants — what each batch lane
        was doing), pid 2 "requests" (one tid per rid: queued / prefill
        / decode phase spans + token instants — each request's own
        timeline).  Extra top-level keys (`repro_records`,
        `repro_summary`) carry the derived records; Perfetto ignores
        them, `python -m repro.obs report` reads them.
        """
        t0 = self._t0 if self._t0 is not None else 0.0

        def us(t):
            return round((t - t0) * 1e6, 1)

        ev: List[dict] = []

        def meta(pid, name, tid=None):
            if tid is None:
                ev.append({"ph": "M", "name": "process_name", "pid": pid,
                           "args": {"name": name}})
            else:
                ev.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})

        def span(pid, tid, name, a, b, **args):
            ev.append({"ph": "X", "pid": pid, "tid": tid, "name": name,
                       "ts": us(a), "dur": max(round((b - a) * 1e6, 1), 0),
                       "args": args})

        def instant(pid, tid, name, t, **args):
            ev.append({"ph": "i", "s": "t", "pid": pid, "tid": tid,
                       "name": name, "ts": us(t), "args": args})

        meta(0, "engine")
        meta(0, "steps", tid=0)
        meta(1, "slots")
        meta(2, "requests")

        for s0, s1, active, slots, queued in self._steps:
            span(0, 0, "step", s0, s1, active=active, slots=slots,
                 queued=queued)

        slots_seen = set()
        last_t = max([s1 for _, s1, *_ in self._steps] or [t0])
        for rec in self.records():
            end = rec.finish_t if rec.finish_t is not None else last_t
            start = rec.submit_t if rec.submit_t is not None \
                else rec.queued_t
            meta(2, f"req {rec.rid}", tid=rec.rid)
            if start is not None:
                span(2, rec.rid, f"request {rec.rid}", start, end,
                     prompt_len=rec.prompt_len, tokens=rec.tokens,
                     finish_reason=rec.finish_reason)
            if rec.queued_t is not None and rec.admitted_t is not None:
                span(2, rec.rid, "queued", rec.queued_t, rec.admitted_t,
                     blocked=rec.blocked)
            if rec.admitted_t is not None and rec.first_token_t is not None:
                span(2, rec.rid, "prefill", rec.admitted_t,
                     rec.first_token_t,
                     windows=len(rec.prefill_windows))
            if rec.first_token_t is not None:
                span(2, rec.rid, "decode", rec.first_token_t, end,
                     tokens=rec.tokens)
            for (p0, _, policy), (p1, _, _) in zip(rec.preempt_events,
                                                   rec.resume_events):
                span(2, rec.rid, "preempted", p0, p1, policy=policy)
            for t in rec.token_ts:
                instant(2, rec.rid, "tok", t)
            if rec.slot is not None:
                slots_seen.add(rec.slot)
                for w0, w1, ntok in rec.prefill_windows:
                    span(1, rec.slot, f"prefill rid={rec.rid}", w0, w1,
                         tokens=ntok)
                for t in rec.token_ts:
                    instant(1, rec.slot, f"tok rid={rec.rid}", t)
        for slot in sorted(slots_seen):
            meta(1, f"slot {slot}", tid=slot)

        doc = {"traceEvents": ev, "displayTimeUnit": "ms",
               "repro_records": [r.to_json() for r in self.records()],
               "repro_summary": self.summary()}
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            with open(path, "w") as f:
                json.dump(doc, f, indent=1)
        return doc
