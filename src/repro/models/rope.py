"""Rotary position embeddings: standard, partial (pythia/stablelm/chatglm),
and M-RoPE (qwen2-vl).

All functions take q/k of shape (B, H, N, D) and positions; M-RoPE takes
positions (3, B, N) — temporal/height/width streams (equal for text).
"""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def _rope_angles(positions, dim: int, theta: float):
    """positions (..., N) -> cos/sin (..., N, dim/2)."""
    inv = 1.0 / theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim)
    ang = positions.astype(F32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """Rotate-half (GPT-NeoX style) on the last dim. x: (..., N, dim)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_rope(x, positions, kind: str = "standard", fraction: float = 1.0,
               theta: float = 10000.0, mrope_sections=(16, 24, 24)):
    """x: (B, H, N, D); positions: (B, N) or (3, B, N) for mrope."""
    if kind in ("none", "sinusoid"):
        return x
    d = x.shape[-1]
    dtype = x.dtype
    xf = x.astype(F32)

    if kind == "mrope":
        assert positions.ndim == 3, "mrope needs (3, B, N) positions"
        # sections partition the dim/2 frequency slots across t/h/w streams
        cos_l, sin_l = [], []
        start = 0
        full_cos, full_sin = [], []
        for s, pos in zip(mrope_sections, positions):
            cos, sin = _rope_angles(pos, d, theta)  # (B, N, d/2)
            full_cos.append(cos[..., start:start + s])
            full_sin.append(sin[..., start:start + s])
            start += s
        cos = jnp.concatenate(full_cos, -1)[:, None]  # (B,1,N,d/2)
        sin = jnp.concatenate(full_sin, -1)[:, None]
        return _rotate(xf, cos, sin).astype(dtype)

    rot_dim = d if kind == "standard" else int(d * fraction)
    rot_dim -= rot_dim % 2
    cos, sin = _rope_angles(positions, rot_dim, theta)  # (B, N, rot/2)
    cos, sin = cos[:, None], sin[:, None]               # broadcast heads
    x_rot = _rotate(xf[..., :rot_dim], cos, sin)
    if rot_dim < d:
        x_rot = jnp.concatenate([x_rot, xf[..., rot_dim:]], -1)
    return x_rot.astype(dtype)
