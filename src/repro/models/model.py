"""Full model assembly: embed -> scan over blocks -> norm -> unembed.

Covers every assigned architecture family:
  dense / moe / vlm   — decoder-only LM (attention or MLA mixer, MoE FFN)
  ssm                 — pure Mamba-2 stack
  hybrid              — zamba2: groups of mamba layers + one SHARED
                        attention block re-applied between groups
  encdec              — whisper: bidirectional encoder + cross-attn decoder

Layers are scanned with stacked params (compact HLO for 60+ layer archs);
cfg.remat wraps the scan body in jax.checkpoint.  The training loss is a
sequence-chunked cross-entropy that never materializes (B, N, V) logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.act_sharding import BATCH, MODEL, constrain
from repro.models import blocks as blk
from repro.models.common import dtype_of, embed_init, embed_lookup, \
    norm_apply, norm_init, sinusoid_positions, dense, dense_init, unembed

F32 = jnp.float32


def _stack_init(init_fn, key, num: int):
    """vmap an init over `num` layer keys -> params stacked on axis 0."""
    return jax.vmap(init_fn)(jax.random.split(key, num))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, pd),
              "ln_f": norm_init(cfg.d_model, cfg.norm, pd)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                       dtype=pd)

    if cfg.family == "encdec":
        params["enc_blocks"] = _stack_init(
            lambda k: blk.enc_block_init(k, cfg, pd), ks[2],
            cfg.encoder_layers)
        params["ln_enc"] = norm_init(cfg.d_model, cfg.norm, pd)
        params["dec_blocks"] = _stack_init(
            lambda k: blk.xdec_block_init(k, cfg, pd), ks[3], cfg.num_layers)
        return params

    if cfg.family == "hybrid":
        g, m, t = cfg.hybrid_groups, cfg.hybrid_mamba_per_group, \
            cfg.hybrid_tail
        params["mamba_groups"] = _stack_init(
            lambda k: _stack_init(lambda k2: blk.block_init(k2, cfg, pd),
                                  k, m), ks[2], g)
        params["shared_attn"] = blk.block_init(
            ks[3], _attn_variant(cfg), pd)
        if t:
            params["tail"] = _stack_init(
                lambda k: blk.block_init(k, cfg, pd), ks[4], t)
        return params

    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    if n_prefix:
        params["prefix_blocks"] = [
            blk.block_init(k, cfg, pd, dense_ffn=True)
            for k in jax.random.split(ks[2], n_prefix)]
    params["blocks"] = _stack_init(
        lambda k: blk.block_init(k, cfg, pd), ks[3],
        cfg.num_layers - n_prefix)
    return params


def _attn_variant(cfg):
    """Config view for zamba2's shared attention block (attention mixer)."""
    import dataclasses
    return dataclasses.replace(cfg, mixer="attention", moe=None)


# ---------------------------------------------------------------------------
# Forward (training) — returns final hidden + aux loss
# ---------------------------------------------------------------------------

def _positions(cfg, batch, tokens):
    if "positions" in batch:
        return batch["positions"]
    b, n = tokens.shape
    return jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (b, n))


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan_blocks(blocks, cfg, x, positions, compute_dtype):
    def body(carry, layer_params):
        h, aux = carry
        y, aux_i = blk.block_apply(layer_params, cfg, h, positions,
                                   compute_dtype)
        y = constrain(y, BATCH, None, None)
        return (y, aux + aux_i.astype(F32)), None

    (x, aux), _ = lax.scan(_maybe_remat(body, cfg), (x, F32(0.0)), blocks)
    return x, aux


def forward_hidden(params, cfg, batch):
    """batch: {"tokens": (B, N) int32, ...}.  Returns (hidden, aux_loss)."""
    cdt = dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    positions = _positions(cfg, batch, tokens)
    x = constrain(embed_lookup(params["embed"], tokens, cdt),
                  BATCH, None, None)
    aux = F32(0.0)

    if cfg.family == "encdec":
        enc = batch["frames"].astype(cdt)
        enc = enc + sinusoid_positions(enc.shape[1], cfg.d_model).astype(cdt)

        def enc_body(h, lp):
            return constrain(blk.enc_block_apply(lp, cfg, h, cdt),
                             BATCH, None, None), None
        enc, _ = lax.scan(_maybe_remat(enc_body, cfg), enc,
                          params["enc_blocks"])
        enc = norm_apply(params["ln_enc"], enc, cfg.norm)

        x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(cdt)

        def dec_body(h, lp):
            return constrain(
                blk.xdec_block_apply(lp, cfg, h, enc, positions, cdt),
                BATCH, None, None), None
        x, _ = lax.scan(_maybe_remat(dec_body, cfg), x,
                        params["dec_blocks"])

    elif cfg.family == "hybrid":
        def group_body(carry, group_params):
            h, aux_c = carry
            def inner(c2, lp):
                y, a = blk.block_apply(lp, cfg, c2[0], positions, cdt)
                y = constrain(y, BATCH, None, None)
                return (y, c2[1] + a.astype(F32)), None
            (h, aux_c), _ = lax.scan(_maybe_remat(inner, cfg), (h, aux_c),
                                     group_params)
            h, a = blk.block_apply(params["shared_attn"], _attn_variant(cfg),
                                   h, positions, cdt)
            h = constrain(h, BATCH, None, None)
            return (h, aux_c + a.astype(F32)), None
        # remat at the group level too: the shared attention block's
        # internals must not be stashed for all 13 group applications
        (x, aux), _ = lax.scan(_maybe_remat(group_body, cfg), (x, aux),
                               params["mamba_groups"])
        if "tail" in params:
            x, a = _scan_blocks(params["tail"], cfg, x, positions, cdt)
            aux = aux + a

    else:
        if cfg.rope_kind == "sinusoid":
            x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(cdt)
        for lp in params.get("prefix_blocks", []):
            x, a = blk.block_apply(lp, cfg, x, positions, cdt)
            aux = aux + a.astype(F32)
        x, a = _scan_blocks(params["blocks"], cfg, x, positions, cdt)
        aux = aux + a

    return norm_apply(params["ln_f"], x, cfg.norm), aux


def _unembed_weight(params, cfg):
    """(d_model, vocab) in f32 for the loss."""
    if cfg.tie_embeddings:
        return params["embed"]["table"].astype(F32).T
    return params["lm_head"]["w"].astype(F32)


def forward_logits(params, cfg, batch):
    """Full logits — small-scale use only (examples, decode)."""
    hidden, _ = forward_hidden(params, cfg, batch)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], hidden.astype(F32))
    else:
        logits = dense(params["lm_head"], hidden, F32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Loss — sequence-chunked cross-entropy (never materializes (B, N, V))
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden, w, labels, mask, chunk: int = 512):
    """hidden: (B, N, d); w: (d, V) f32; labels/mask: (B, N).

    Scans over N in chunks; jax.checkpoint on the body keeps only chunk
    inputs as residuals so the backward recomputes per-chunk logits.
    """
    b, n, d = hidden.shape
    c = min(chunk, n)
    t = -(-n // c)
    n_pad = t * c
    if n_pad != n:
        hidden = jnp.pad(hidden, [(0, 0), (0, n_pad - n), (0, 0)])
        labels = jnp.pad(labels, [(0, 0), (0, n_pad - n)])
        mask = jnp.pad(mask, [(0, 0), (0, n_pad - n)])
    h_c = jnp.moveaxis(hidden.reshape(b, t, c, d), 1, 0)
    y_c = jnp.moveaxis(labels.reshape(b, t, c), 1, 0)
    m_c = jnp.moveaxis(mask.reshape(b, t, c), 1, 0)

    @jax.checkpoint
    def body(carry, inp):
        loss_sum, count = carry
        h, y, m = inp
        logits = jnp.einsum("bcd,dv->bcv", h.astype(F32), w,
                            preferred_element_type=F32)
        logits = constrain(logits, BATCH, None, MODEL)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((logz - ll) * m)
        count = count + jnp.sum(m)
        return (loss_sum, count), None

    (loss_sum, count), _ = lax.scan(body, (F32(0.0), F32(0.0)),
                                    (h_c, y_c, m_c))
    return loss_sum / jnp.maximum(count, 1.0)


def loss_fn(params, cfg, batch):
    """Next-token CE + MoE aux.  batch needs "tokens" (+family extras)."""
    hidden, aux = forward_hidden(params, cfg, batch)
    tokens = batch["tokens"]
    labels = jnp.pad(tokens[:, 1:], [(0, 0), (0, 1)])
    mask = jnp.ones_like(tokens, F32).at[:, -1].set(0.0)
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(F32)
    w = _unembed_weight(params, cfg)
    ce = chunked_cross_entropy(hidden, w, labels, mask)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    return ce + aux_w * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def _zeros_like_struct(struct):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


def _stacked_cache(cfg, num: int, batch: int, max_len: int, kind="block",
                   dtype=jnp.bfloat16):
    one = (blk.block_init_cache(cfg, batch, max_len, dtype) if kind == "block"
           else {"self": blk.block_init_cache(cfg, batch, max_len, dtype),
                 "cross": None})
    return jax.tree.map(
        lambda x: jnp.zeros((num,) + x.shape, x.dtype), one)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    """Decode cache for the whole model (+ position counter)."""
    if dtype is None:
        dtype = dtype_of(cfg.compute_dtype)
    if cfg.family == "encdec":
        from repro.mixers.cache import CrossState
        hd = cfg.resolved_head_dim
        hkv = cfg.num_kv_heads
        self_c = _stacked_cache(cfg, cfg.num_layers, batch, max_len,
                                dtype=dtype)
        cross = CrossState(
            s=jnp.zeros((cfg.num_layers, batch, hkv, hd, hd + 1), F32),
            p=jnp.zeros((cfg.num_layers, batch, hkv, hd + 1), F32))
        return {"self": self_c, "cross": cross,
                "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "hybrid":
        g, m, t = cfg.hybrid_groups, cfg.hybrid_mamba_per_group, \
            cfg.hybrid_tail
        one_m = blk.block_init_cache(cfg, batch, max_len, dtype)
        acfg = _attn_variant(cfg)
        one_a = blk.block_init_cache(acfg, batch, max_len, dtype)
        cache = {
            "mamba": jax.tree.map(
                lambda x: jnp.zeros((g, m) + x.shape, x.dtype), one_m),
            "shared": jax.tree.map(
                lambda x: jnp.zeros((g,) + x.shape, x.dtype), one_a),
            "pos": jnp.zeros((batch,), jnp.int32)}
        if t:
            cache["tail"] = jax.tree.map(
                lambda x: jnp.zeros((t,) + x.shape, x.dtype), one_m)
        return cache
    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    cache = {"blocks": _stacked_cache(cfg, cfg.num_layers - n_prefix,
                                      batch, max_len, dtype=dtype),
             "pos": jnp.zeros((batch,), jnp.int32)}
    if n_prefix:
        cache["prefix"] = [blk.block_init_cache(cfg, batch, max_len, dtype)
                           for _ in range(n_prefix)]
    if cfg.rope_kind == "mrope":
        # next rope position value per sequence (can lag the token count
        # because image patches share t/h/w grid positions)
        cache["rope_pos"] = jnp.zeros((batch,), jnp.int32)
    return cache


def prefill(params, cfg, batch, cache):
    """Run a prompt (or a continuation window of one) against `cache`;
    returns (last-token logits (B, V), cache).

    Positions and the pos counter CONTINUE from cache["pos"], so
    chunked prefill (feeding the prompt window by window, carrying the
    recurrent state) is exact — see train/step.py::build_prefill_step.
    """
    cdt = dtype_of(cfg.compute_dtype)
    tokens = batch["tokens"]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        b_, n_ = tokens.shape
        positions = (cache["pos"][:, None]
                     + jnp.arange(n_, dtype=jnp.int32)[None])
    x = embed_lookup(params["embed"], tokens, cdt)

    if cfg.family == "encdec":
        enc = batch["frames"].astype(cdt)
        enc = enc + sinusoid_positions(enc.shape[1], cfg.d_model).astype(cdt)

        def enc_body(h, lp):
            return blk.enc_block_apply(lp, cfg, h, cdt), None
        enc, _ = lax.scan(enc_body, enc, params["enc_blocks"])
        enc = norm_apply(params["ln_enc"], enc, cfg.norm)
        x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(cdt)
        # NOTE: whisper prefill is single-shot (cross-attn state is
        # precomputed here); chunked prefill targets decoder-only archs

        def dec_body(h, inp):
            lp, lc = inp
            y, nc = blk.xdec_block_prefill(lp, cfg, h, enc, positions,
                                           {"self": lc, "cross": None}, cdt)
            return y, (nc["self"], nc["cross"])
        x, (self_c, cross_c) = lax.scan(
            dec_body, x, (params["dec_blocks"], cache["self"]))
        new_cache = {"self": self_c, "cross": cross_c,
                     "pos": cache["pos"] + tokens.shape[1]}

    elif cfg.family == "hybrid":
        def group_body(h, inp):
            gp, gc_m, gc_a = inp
            def inner(h2, inp2):
                lp, lc = inp2
                y, nc = blk.block_prefill(lp, cfg, h2, positions, lc, cdt)
                return y, nc
            h, nc_m = lax.scan(inner, h, (gp, gc_m))
            h, nc_a = blk.block_prefill(params["shared_attn"],
                                        _attn_variant(cfg), h, positions,
                                        gc_a, cdt)
            return h, (nc_m, nc_a)
        x, (m_c, a_c) = lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["mamba"], cache["shared"]))
        new_cache = {"mamba": m_c, "shared": a_c,
                     "pos": cache["pos"] + tokens.shape[1]}
        if "tail" in params:
            def tail_body(h, inp):
                lp, lc = inp
                y, nc = blk.block_prefill(lp, cfg, h, positions, lc, cdt)
                return y, nc
            x, t_c = lax.scan(tail_body, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = t_c

    else:
        if cfg.rope_kind == "sinusoid":
            x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(cdt)
        new_cache = {"pos": cache["pos"] + tokens.shape[1]}
        if "prefix_blocks" in params:
            new_cache["prefix"] = []
            for lp, lc in zip(params["prefix_blocks"], cache["prefix"]):
                x, nc = blk.block_prefill(lp, cfg, x, positions, lc, cdt)
                new_cache["prefix"].append(nc)

        def body(h, inp):
            lp, lc = inp
            y, nc = blk.block_prefill(lp, cfg, h, positions, lc, cdt)
            return y, nc
        x, b_c = lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = b_c
        if cfg.rope_kind == "mrope":
            new_cache["rope_pos"] = (
                positions[:, :, -1].max(axis=0) + 1).astype(jnp.int32)

    x = norm_apply(params["ln_f"], x[:, -1:], cfg.norm)
    logits = _last_logits(params, cfg, x)
    return logits, new_cache


def _last_logits(params, cfg, x_last):
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x_last.astype(F32))
    else:
        logits = dense(params["lm_head"], x_last, F32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits[:, 0]


def _sinusoid_at(pos, d: int):
    """Per-sequence sinusoidal embedding at positions pos (B,) -> (B, 1, d)."""
    inv = jnp.exp(-jnp.arange(0, d, 2, dtype=F32) / d * jnp.log(10000.0))
    ang = pos.astype(F32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[:, None]


def decode_step(params, cfg, cache, tokens):
    """tokens: (B,) int32 — one new token per sequence.

    With the paper's linear backend this is O(D^2) per head regardless of
    context length (the cache is the recurrent state).
    """
    cdt = dtype_of(cfg.compute_dtype)
    b = tokens.shape[0]
    pos = cache["pos"]  # (B,) — slots may be at different depths
    position = pos[:, None].astype(jnp.int32)
    if cfg.rope_kind == "mrope":
        # text decode: all three streams advance together from rope_pos
        position = jnp.broadcast_to(cache["rope_pos"][None, :, None],
                                    (3, b, 1))
    x = embed_lookup(params["embed"], tokens[:, None], cdt)

    if cfg.family == "encdec":
        x = x + _sinusoid_at(pos, cfg.d_model).astype(cdt)

        def body(h, inp):
            lp, lc_self, lc_cross = inp
            y, nc = blk.xdec_block_decode(
                lp, cfg, h, position,
                {"self": lc_self, "cross": lc_cross}, cdt)
            return y, (nc["self"], nc["cross"])
        x, (self_c, cross_c) = lax.scan(
            body, x, (params["dec_blocks"], cache["self"], cache["cross"]))
        new_cache = {"self": self_c, "cross": cross_c, "pos": pos + 1}

    elif cfg.family == "hybrid":
        def group_body(h, inp):
            gp, gc_m, gc_a = inp
            def inner(h2, inp2):
                lp, lc = inp2
                y, nc = blk.block_decode(lp, cfg, h2, position, lc, cdt)
                return y, nc
            h, nc_m = lax.scan(inner, h, (gp, gc_m))
            h, nc_a = blk.block_decode(params["shared_attn"],
                                       _attn_variant(cfg), h, position,
                                       gc_a, cdt)
            return h, (nc_m, nc_a)
        x, (m_c, a_c) = lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["mamba"], cache["shared"]))
        new_cache = {"mamba": m_c, "shared": a_c, "pos": pos + 1}
        if "tail" in params:
            def tail_body(h, inp):
                lp, lc = inp
                y, nc = blk.block_decode(lp, cfg, h, position, lc, cdt)
                return y, nc
            x, t_c = lax.scan(tail_body, x, (params["tail"], cache["tail"]))
            new_cache["tail"] = t_c

    else:
        if cfg.rope_kind == "sinusoid":
            x = x + _sinusoid_at(pos, cfg.d_model).astype(cdt)
        new_cache = {"pos": pos + 1}
        if "prefix_blocks" in params:
            new_cache["prefix"] = []
            for lp, lc in zip(params["prefix_blocks"], cache["prefix"]):
                x, nc = blk.block_decode(lp, cfg, x, position, lc, cdt)
                new_cache["prefix"].append(nc)

        def body(h, inp):
            lp, lc = inp
            y, nc = blk.block_decode(lp, cfg, h, position, lc, cdt)
            return y, nc
        x, b_c = lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = b_c
        if cfg.rope_kind == "mrope":
            new_cache["rope_pos"] = cache["rope_pos"] + 1

    x = norm_apply(params["ln_f"], x, cfg.norm)
    return _last_logits(params, cfg, x), new_cache
