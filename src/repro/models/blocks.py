"""Transformer / SSM blocks: norm + mixer + FFN with residuals.

The token mixer is resolved ONCE per call through the attention-backend
registry (`repro.mixers.get_backend`) — blocks never branch on backend
or mixer strings.  Every backend exposes (init, apply, init_cache,
prefill, decode), so model.py can scan over stacked layer params
uniformly; `backend.fuses_ffn` tells the block whether the mixer already
contains its channel mixing (mamba2).  `apply` returns (y, aux) where
aux is the MoE load-balancing loss (0.0 otherwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mixers import get_backend
from repro.models import moe as moe_mod
from repro.models.common import mlp_apply, mlp_init, norm_apply, norm_init

ZERO = jnp.float32(0.0)


def _ffn_init(key, cfg, dtype, dense_ffn: bool = False):
    """MoE unless cfg.moe is None or this layer is forced dense."""
    if cfg.moe is not None and not dense_ffn:
        return moe_mod.moe_init(key, cfg, dtype)
    d_ff = cfg.d_ff
    if cfg.moe is not None and dense_ffn:
        d_ff = cfg.moe.dense_d_ff or cfg.d_ff
    return mlp_init(key, cfg.d_model, d_ff, cfg.mlp_act, dtype)


def _ffn_apply(p, cfg, x, compute_dtype, dropless: bool = False):
    if "router" in p:  # structural marker: MoE FFN
        return moe_mod.moe_apply(p, cfg, x, compute_dtype, dropless)
    return mlp_apply(p, x, cfg.mlp_act, compute_dtype), ZERO


# ---------------------------------------------------------------------------
# Decoder block (causal mixer + FFN)
# ---------------------------------------------------------------------------

def block_init(key, cfg, dtype=jnp.float32, dense_ffn: bool = False):
    backend = get_backend(cfg)
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm, dtype),
         "mixer": backend.init(k1, cfg, dtype)}
    if not backend.fuses_ffn:
        p["ln2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["ffn"] = _ffn_init(k2, cfg, dtype, dense_ffn)
    return p


def block_apply(p, cfg, x, positions, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn_out = backend.apply(p["mixer"], cfg, h, positions, compute_dtype)
    if backend.fuses_ffn:
        return x + attn_out, ZERO
    if cfg.parallel_residual:
        ffn_out, aux = _ffn_apply(p["ffn"],
                                  cfg, norm_apply(p["ln2"], x, cfg.norm),
                                  compute_dtype)
        return x + attn_out + ffn_out, aux
    x = x + attn_out
    ffn_out, aux = _ffn_apply(p["ffn"], cfg,
                              norm_apply(p["ln2"], x, cfg.norm),
                              compute_dtype)
    return x + ffn_out, aux


def block_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    return get_backend(cfg).init_cache(cfg, batch, max_len, dtype)


def block_prefill(p, cfg, x, positions, cache, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn_out, cache = backend.prefill(p["mixer"], cfg, h, positions, cache,
                                      compute_dtype)
    if backend.fuses_ffn:
        return x + attn_out, cache
    if cfg.parallel_residual:
        ffn_out, _ = _ffn_apply(p["ffn"], cfg,
                                norm_apply(p["ln2"], x, cfg.norm),
                                compute_dtype)
        return x + attn_out + ffn_out, cache
    x = x + attn_out
    ffn_out, _ = _ffn_apply(p["ffn"], cfg,
                            norm_apply(p["ln2"], x, cfg.norm),
                            compute_dtype)
    return x + ffn_out, cache


def block_decode(p, cfg, x, position, cache, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn_out, cache = backend.decode(p["mixer"], cfg, h, position, cache,
                                     compute_dtype)
    if backend.fuses_ffn:
        return x + attn_out, cache
    if cfg.parallel_residual:
        ffn_out, _ = _ffn_apply(p["ffn"], cfg,
                                norm_apply(p["ln2"], x, cfg.norm),
                                compute_dtype, dropless=True)
        return x + attn_out + ffn_out, cache
    x = x + attn_out
    ffn_out, _ = _ffn_apply(p["ffn"], cfg,
                            norm_apply(p["ln2"], x, cfg.norm),
                            compute_dtype, dropless=True)
    return x + ffn_out, cache


# ---------------------------------------------------------------------------
# Encoder block (bidirectional self-attention; whisper encoder)
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg, dtype=jnp.float32):
    backend = get_backend(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": norm_init(cfg.d_model, cfg.norm, dtype),
            "attn": backend.init(k1, cfg, dtype),
            "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
            "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}


def enc_block_apply(p, cfg, x, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    x = x + backend.apply_noncausal(p["attn"], cfg, h, h,
                                    compute_dtype=compute_dtype)
    x = x + mlp_apply(p["ffn"], norm_apply(p["ln2"], x, cfg.norm),
                      cfg.mlp_act, compute_dtype)
    return x


# ---------------------------------------------------------------------------
# Cross-attention decoder block (whisper decoder)
# ---------------------------------------------------------------------------

def xdec_block_init(key, cfg, dtype=jnp.float32):
    backend = get_backend(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": norm_init(cfg.d_model, cfg.norm, dtype),
            "self": backend.init(k1, cfg, dtype),
            "ln_x": norm_init(cfg.d_model, cfg.norm, dtype),
            "cross": backend.init(k2, cfg, dtype),
            "ln2": norm_init(cfg.d_model, cfg.norm, dtype),
            "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)}


def xdec_block_apply(p, cfg, x, enc, positions, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    x = x + backend.apply(p["self"], cfg, h, positions, compute_dtype)
    h = norm_apply(p["ln_x"], x, cfg.norm)
    x = x + backend.apply_noncausal(p["cross"], cfg, h, enc,
                                    compute_dtype=compute_dtype)
    x = x + mlp_apply(p["ffn"], norm_apply(p["ln2"], x, cfg.norm),
                      cfg.mlp_act, compute_dtype)
    return x


def xdec_block_prefill(p, cfg, x, enc, positions, cache, compute_dtype=None):
    """cache: {"self": mixer cache, "cross": CrossState}."""
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn_out, self_cache = backend.prefill(p["self"], cfg, h, positions,
                                           cache["self"], compute_dtype)
    x = x + attn_out
    cross_state = backend.cross_precompute(p["cross"], cfg, enc,
                                           compute_dtype)
    h = norm_apply(p["ln_x"], x, cfg.norm)
    x = x + backend.apply_noncausal(p["cross"], cfg, h, enc,
                                    compute_dtype=compute_dtype)
    x = x + mlp_apply(p["ffn"], norm_apply(p["ln2"], x, cfg.norm),
                      cfg.mlp_act, compute_dtype)
    return x, {"self": self_cache, "cross": cross_state}


def xdec_block_decode(p, cfg, x, position, cache, compute_dtype=None):
    backend = get_backend(cfg)
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn_out, self_cache = backend.decode(p["self"], cfg, h, position,
                                          cache["self"], compute_dtype)
    x = x + attn_out
    h = norm_apply(p["ln_x"], x, cfg.norm)
    x = x + backend.cross_decode(p["cross"], cfg, h, cache["cross"],
                                 compute_dtype)
    x = x + mlp_apply(p["ffn"], norm_apply(p["ln2"], x, cfg.norm),
                      cfg.mlp_act, compute_dtype)
    return x, {"self": self_cache, "cross": cache["cross"]}
