"""Modality frontends — STUBS per assignment.

The [audio]/[vlm] architectures specify the transformer BACKBONE only;
`input_specs()` feeds precomputed frame/patch embeddings, so these stubs
exist to document the interface and to let the examples synthesize
plausible inputs.  A real deployment would replace them with the conv
mel-spectrogram frontend (whisper) / ViT patchifier (qwen2-vl).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frames_stub(key, batch: int, num_frames: int, d_model: int,
                      dtype=jnp.float32):
    """Stand-in for whisper's conv1d(mel) encoder input: (B, T, d_model)."""
    return jax.random.normal(key, (batch, num_frames, d_model), dtype) * 0.02


def vision_positions_stub(batch: int, seq_len: int, grid=(1, 16, 16)):
    """M-RoPE (t, h, w) positions for a text+image stream: (3, B, N).

    The first grid[0]*grid[1]*grid[2] tokens are image patches laid out on
    the (t, h, w) grid; the rest are text with all three streams equal
    (qwen2-vl's convention).
    """
    t, h, w = grid
    n_img = t * h * w
    n_img = min(n_img, seq_len)
    idx = jnp.arange(n_img)
    tpos = idx // (h * w)
    hpos = (idx // w) % h
    wpos = idx % w
    text = jnp.arange(seq_len - n_img) + (tpos.max() + 1 if n_img else 0)
    pos3 = jnp.stack([
        jnp.concatenate([tpos, text]),
        jnp.concatenate([hpos, text]),
        jnp.concatenate([wpos, text]),
    ]).astype(jnp.int32)
    return jnp.broadcast_to(pos3[:, None], (3, batch, seq_len))
