"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch (EP).

Design for the 512-chip dry-run: the dispatch never materializes a
(tokens, experts, capacity) one-hot.  Instead each token replica's slot
is computed with an exclusive cumsum over the token axis, token states
are scattered into a dense (E, capacity, d) buffer (dropping overflow),
experts run as one batched einsum — sharded experts-over-"model"
(expert parallelism), capacity-over-"data" — and outputs are gathered
back and combined with the router weights.  FLOPs stay
O(tokens * top_k * d * d_ff * capacity_factor): linear in tokens.

Shared experts (DeepSeek-V2 / Moonlight) run densely on every token.
An auxiliary load-balancing loss (Switch-style) is returned to the
caller and added to the training objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import MODEL, constrain
from repro.models.common import dense_init

F32 = jnp.float32


def _expert_ffn_init(key, d_model: int, d_ff: int, num: int, dtype=F32):
    """num stacked SwiGLU experts: wi/wg (E, d, f), wo (E, f, d)."""
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = (1.0 / d_model) ** 0.5
    s_out = (1.0 / d_ff) ** 0.5
    mk = lambda k, shape, s: (jax.random.normal(k, shape, F32) * s).astype(dtype)  # noqa: E731
    return {
        "wi": mk(k1, (num, d_model, d_ff), s_in),
        "wg": mk(k2, (num, d_model, d_ff), s_in),
        "wo": mk(k3, (num, d_ff, d_model), s_out),
    }


def moe_init(key, cfg, dtype=F32):
    m = cfg.moe
    ks = jax.random.split(key, 3)
    p = {
        "router": dense_init(ks[0], cfg.d_model, m.num_experts, dtype=F32),
        "experts": _expert_ffn_init(ks[1], cfg.d_model, m.d_expert,
                                    m.num_experts, dtype),
    }
    if m.num_shared:
        p["shared"] = _expert_ffn_init(ks[2], cfg.d_model, m.d_expert,
                                       m.num_shared, dtype)
    return p


def _batched_swiglu(p, x):
    """x: (E, C, d) -> (E, C, d) through per-expert SwiGLU."""
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(x.dtype)))
         * jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(x.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))


def moe_apply(p, cfg, x, compute_dtype=None, dropless: bool = False):
    """x: (B, N, d).  Returns (y, aux_loss).

    dropless=True sizes capacity to the worst case (tokens * top_k) so no
    token is ever dropped — used on the decode path where tokens is tiny
    and routing fidelity matters; training uses the capacity factor.

    When a mesh policy with a "model" axis is installed (production /
    dry-run), dispatch runs expert-parallel via moe_apply_ep.
    """
    from repro.distributed.act_sharding import current_mesh
    mesh = current_mesh()
    if mesh is not None and mesh.shape.get("model", 1) > 1 \
            and cfg.moe.num_experts % mesh.shape["model"] == 0:
        return moe_apply_ep(p, cfg, x, mesh, compute_dtype, dropless)
    m = cfg.moe
    b, n, d = x.shape
    tokens = b * n
    xt = x.reshape(tokens, d)
    if compute_dtype is not None:
        xt = xt.astype(compute_dtype)

    logits = jnp.einsum("td,de->te", xt.astype(F32),
                        p["router"]["w"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)      # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                # renormalize

    # Switch-style load-balancing aux loss
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], m.num_experts, dtype=F32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = m.num_experts * jnp.sum(density * density_proxy)

    if dropless:
        capacity = tokens * m.top_k
    else:
        capacity = int(tokens * m.top_k * m.capacity_factor
                       / m.num_experts) + 1

    # slot of each (token, k) replica within its expert: exclusive cumsum
    onehot = jax.nn.one_hot(expert_ids, m.num_experts,
                            dtype=jnp.int32)                   # (T, K, E)
    flat = onehot.reshape(tokens * m.top_k, m.num_experts)
    slots_e = jnp.cumsum(flat, axis=0) - flat                  # (T*K, E)
    slot = jnp.sum(slots_e * flat, axis=-1)                    # (T*K,)
    eid = expert_ids.reshape(-1)
    keep = slot < capacity
    # dropped replicas scatter to a dump row (capacity slot of expert 0)
    target = jnp.where(keep, eid * capacity + slot,
                       m.num_experts * capacity)

    buf = jnp.zeros((m.num_experts * capacity + 1, d), xt.dtype)
    xr = jnp.repeat(xt, m.top_k, axis=0)                       # (T*K, d)
    buf = buf.at[target].set(xr, mode="drop")
    expert_in = buf[:-1].reshape(m.num_experts, capacity, d)
    expert_in = constrain(expert_in, MODEL, None, None)

    expert_out = _batched_swiglu(p["experts"], expert_in)
    expert_out = constrain(expert_out, MODEL, None, None)
    out_flat = jnp.concatenate(
        [expert_out.reshape(-1, d), jnp.zeros((1, d), expert_out.dtype)])
    gathered = out_flat[target]                                # (T*K, d)
    gates = jnp.where(keep, gate_vals.reshape(-1), 0.0)
    y = jnp.sum(gathered.reshape(tokens, m.top_k, d)
                * gates.reshape(tokens, m.top_k, 1).astype(gathered.dtype),
                axis=1)

    if "shared" in p:
        y = y + _shared_experts(p["shared"], xt)
    return y.reshape(b, n, d), aux_loss


def _shared_experts(p_shared, xt):
    """Shared experts as plain per-token MLPs.

    (A broadcast to (S, tokens, d) + batched einsum replicates the whole
    token stream S times and, sharded, cost a 12 GB/layer all-reduce on
    the dry-run — plain matmuls keep the token dim batch-sharded.)
    """
    y = 0.0
    for s in range(p_shared["wi"].shape[0]):
        h = (jax.nn.silu(xt @ p_shared["wg"][s].astype(xt.dtype))
             * (xt @ p_shared["wi"][s].astype(xt.dtype)))
        y = y + h @ p_shared["wo"][s].astype(xt.dtype)
    return y


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map) — the production path
# ---------------------------------------------------------------------------
#
# pjit's lowering of the capacity scatter merges per-shard buffers with a
# full-buffer all-reduce (observed: 4 GB/layer/device on the 64-expert
# dry-run — the dominant collective cost of the MoE cells).  Expert
# parallelism does it shard-locally instead:
#
#   * tokens stay sharded over ("pod","data"); every model-rank carries
#     the same token shard, so routing + the capacity scatter are
#     REPLICATED local work — no collective at all;
#   * each model-rank slices its E/model_size experts from the local
#     buffer and runs its expert FFNs (weights are model-sharded);
#   * the combine is a partial sum over each rank's own experts followed
#     by ONE psum over "model": (T_local, d) — the minimal payload.
#
# Capacity is per-DP-shard (standard for EP dispatch); the aux loss is
# averaged over the data axes.

def moe_apply_ep(p, cfg, x, mesh, compute_dtype=None,
                 dropless: bool = False):
    from jax.sharding import PartitionSpec as P
    m = cfg.moe
    b, n, d = x.shape
    cdt = compute_dtype or x.dtype
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                       and b % mesh.shape[a] == 0)
    # require the batch to divide across the axes jointly
    dp = 1
    use_axes = []
    for a in batch_axes:
        if b % (dp * mesh.shape[a]) == 0:
            use_axes.append(a)
            dp *= mesh.shape[a]
    bspec = tuple(use_axes) if len(use_axes) > 1 else \
        (use_axes[0] if use_axes else None)
    ep = mesh.shape["model"]
    e_loc = m.num_experts // ep

    def local(xt, router_w, wi, wg, wo, shared):
        # xt: (T_local, d); wi/wg/wo: (E_loc, ...); shared: replicated
        tokens = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(F32),
                            router_w.astype(F32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        density = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], m.num_experts,
                                          dtype=F32), axis=0)
        proxy = jnp.mean(probs, axis=0)
        # pmean the per-expert means FIRST so the product matches the
        # global-batch aux loss exactly
        for a in use_axes:
            density = jax.lax.pmean(density, a)
            proxy = jax.lax.pmean(proxy, a)
        aux = m.num_experts * jnp.sum(density * proxy)

        if dropless:
            cap = tokens * m.top_k
        else:
            cap = int(tokens * m.top_k * m.capacity_factor
                      / m.num_experts) + 1
        onehot = jax.nn.one_hot(expert_ids, m.num_experts, dtype=jnp.int32)
        flat = onehot.reshape(tokens * m.top_k, m.num_experts)
        slots_e = jnp.cumsum(flat, axis=0) - flat
        slot = jnp.sum(slots_e * flat, axis=-1)
        eid = expert_ids.reshape(-1)
        keep = slot < cap
        target = jnp.where(keep, eid * cap + slot, m.num_experts * cap)

        buf = jnp.zeros((m.num_experts * cap + 1, d), xt.dtype)
        xr = jnp.repeat(xt, m.top_k, axis=0)
        buf = buf.at[target].set(xr, mode="drop")

        # my slice of experts
        rank = jax.lax.axis_index("model")
        mybuf = jax.lax.dynamic_slice(
            buf[:-1].reshape(m.num_experts, cap, d),
            (rank * e_loc, 0, 0), (e_loc, cap, d))
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", mybuf,
                                    wg.astype(mybuf.dtype)))
             * jnp.einsum("ecd,edf->ecf", mybuf, wi.astype(mybuf.dtype)))
        out = jnp.einsum("ecf,efd->ecd", h, wo.astype(mybuf.dtype))

        # partial combine over MY experts only, then one psum
        local_t = target - rank * (e_loc * cap)
        in_range = keep & (local_t >= 0) & (local_t < e_loc * cap)
        safe_t = jnp.where(in_range, local_t, e_loc * cap)
        out_flat = jnp.concatenate(
            [out.reshape(-1, d), jnp.zeros((1, d), out.dtype)])
        gathered = out_flat[safe_t]
        gates = jnp.where(in_range, gate_vals.reshape(-1), 0.0)
        y = jnp.sum(gathered.reshape(tokens, m.top_k, d)
                    * gates.reshape(tokens, m.top_k, 1).astype(gathered.dtype),
                    axis=1)
        # psum in the compute dtype: halves the one cross-model payload
        y = jax.lax.psum(y.astype(xt.dtype), "model")
        if shared is not None:
            y = y + _shared_experts(shared, xt)
        return y, aux

    xt = x.reshape(b * n, d).astype(cdt)
    shared = p.get("shared")
    y, aux = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, None), P(), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  None if shared is None else P()),
        out_specs=(P(bspec, None), P()),
        check_vma=False,
    )(xt, p["router"]["w"], p["experts"]["wi"], p["experts"]["wg"],
      p["experts"]["wo"], shared)
    return y.reshape(b, n, d), aux
