"""Attention token mixer: GQA with the paper's linear backend or softmax.

The `linear` backend IS the paper's contribution (core.linear_attention);
`softmax` is the Regular-Attention baseline the paper compares against
(chunked online-softmax on the XLA path — the lax.scan analogue of
FlashAttention-2 — and kernels.flash_attention on TPU).

Interface (shared by all mixers in this package):
    init(key, cfg)                          -> params
    apply(p, cfg, x, positions)             -> y               (causal, train)
    apply_noncausal(p, cfg, x, ctx, pos)    -> y               (encoder/cross)
    init_cache(cfg, batch, max_len, dtype)  -> cache
    prefill(p, cfg, x, positions, cache)    -> (y, cache)
    decode(p, cfg, x, position, cache)      -> (y, cache)      (x: (B, 1, C))
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.chunked import LAState, init_state
from repro.distributed.act_sharding import BATCH, MODEL, constrain
from repro.core.linear_attention import LAConfig, la_attention, \
    la_attention_decode, la_attention_prefill
from repro.core.numerics import l2_normalize
from repro.models.common import dense, dense_init
from repro.models.rope import apply_rope

F32 = jnp.float32


class KVCache(NamedTuple):
    """Softmax-backend decode cache: O(S) per layer."""

    k: jnp.ndarray  # (B, Hkv, S, hd)
    v: jnp.ndarray  # (B, Hkv, S, hd)


def _la_cfg(cfg) -> LAConfig:
    la = cfg.la
    return LAConfig(a=la.a, b=la.b, normalize_qk=la.normalize_qk,
                    chunk=la.chunk, backend=la.backend)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype=F32):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.num_heads * hd,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd,
                         bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(k4, cfg.num_heads * hd, cfg.d_model, dtype=dtype),
    }
    if cfg.la.learnable_coeffs:
        # paper §2.2: f(x) = a + b x with learnable per-layer (a, b),
        # initialized at the Taylor coefficients of exp
        p["la_a"] = jnp.asarray(cfg.la.a, F32)
        p["la_b"] = jnp.asarray(cfg.la.b, F32)
    return p


def _split_heads(x, heads, hd):
    b, n, _ = x.shape
    return x.reshape(b, n, heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, n, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * hd)


def _project_qkv(p, cfg, x, positions, compute_dtype, rope: bool = True):
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], x, compute_dtype), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x, compute_dtype), cfg.num_kv_heads, hd)
    if rope and cfg.rope_kind not in ("none", "sinusoid"):
        q = apply_rope(q, positions, cfg.rope_kind, cfg.rope_fraction,
                       cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_kind, cfg.rope_fraction,
                       cfg.rope_theta, cfg.mrope_sections)
    q = constrain(q, BATCH, MODEL, None, None)
    k = constrain(k, BATCH, MODEL, None, None)
    v = constrain(v, BATCH, MODEL, None, None)
    return q, k, v


# ---------------------------------------------------------------------------
# Softmax baseline — chunked online softmax (O(N) memory on any backend)
# ---------------------------------------------------------------------------

def softmax_chunked(q, k, v, *, causal: bool = True, chunk: int = 512):
    """q: (B,H,Nq,D); k,v: (B,Hkv,Nk,D).  Online-softmax over KV chunks."""
    b, h, nq, d = q.shape
    dv = v.shape[-1]
    hkv, nk = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / d ** 0.5
    c = min(chunk, nk)
    t = -(-nk // c)
    nk_pad = t * c
    padw = [(0, 0), (0, 0), (0, nk_pad - nk), (0, 0)]
    kp, vp = jnp.pad(k, padw), jnp.pad(v, padw)
    k_c = jnp.moveaxis(kp.reshape(b, hkv, t, c, d), 2, 0)
    v_c = jnp.moveaxis(vp.reshape(b, hkv, t, c, dv), 2, 0)
    qg = q.reshape(b, hkv, g, nq, d).astype(F32)
    iq = jax.lax.broadcasted_iota(jnp.int32, (nq, c), 0)
    offs = nk - nq  # causal offset: query i is global position i + offs

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, ti = inp
        s = scale * jnp.einsum("bhgid,bhjd->bhgij", qg, kc.astype(F32),
                               preferred_element_type=F32)
        jk = ti * c + jax.lax.broadcasted_iota(jnp.int32, (nq, c), 1)
        mask = jk < nk  # padded keys never attend
        if causal:
            mask = mask & (iq + offs >= jk)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        pmat = jnp.exp(s - m_new[..., None])
        l = corr * l + pmat.sum(-1)
        acc = corr[..., None] * acc + jnp.einsum(
            "bhgij,bhjd->bhgid", pmat, vc.astype(F32),
            preferred_element_type=F32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, nq), -1e30, F32)
    l0 = jnp.zeros((b, hkv, g, nq), F32)
    a0 = jnp.zeros((b, hkv, g, nq, dv), F32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (k_c, v_c, jnp.arange(t)))
    o = acc / l[..., None]
    return o.reshape(b, h, nq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Apply — train / encoder / serving
# ---------------------------------------------------------------------------

def attn_apply(p, cfg, x, positions, compute_dtype=None):
    """Causal self-attention over the full sequence (training path)."""
    q, k, v = _project_qkv(p, cfg, x, positions, compute_dtype)
    if cfg.attention_backend == "linear":
        if "la_a" in p:  # learnable coefficients (paper §2.2)
            from repro.core.numerics import l2_normalize
            from repro.kernels.ops import la_causal_learnable
            if cfg.la.normalize_qk:
                q, k = l2_normalize(q), l2_normalize(k)
            o = la_causal_learnable(q, k, v, p["la_a"], p["la_b"],
                                    cfg.la.chunk, cfg.la.backend)
        else:
            o = la_attention(q, k, v, _la_cfg(cfg), causal=True)
    else:
        o = softmax_chunked(q, k, v, causal=True)
    return dense(p["wo"], _merge_heads(o), compute_dtype)


def attn_apply_noncausal(p, cfg, x, ctx, positions=None, compute_dtype=None):
    """Bidirectional attention: self (ctx=x, encoder) or cross (ctx=enc)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
    k = _split_heads(dense(p["wk"], ctx, compute_dtype), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], ctx, compute_dtype), cfg.num_kv_heads, hd)
    if positions is not None and cfg.rope_kind not in ("none", "sinusoid"):
        q = apply_rope(q, positions, cfg.rope_kind, cfg.rope_fraction,
                       cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_kind, cfg.rope_fraction,
                       cfg.rope_theta, cfg.mrope_sections)
    if cfg.attention_backend == "linear":
        o = la_attention(q, k, v, _la_cfg(cfg), causal=False)
    else:
        o = softmax_chunked(q, k, v, causal=False)
    return dense(p["wo"], _merge_heads(o), compute_dtype)


# ---------------------------------------------------------------------------
# Serving caches
# ---------------------------------------------------------------------------

def attn_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    if cfg.attention_backend == "linear":
        # paper's deployment story: O(D^2) state, independent of max_len
        return init_state(batch, cfg.num_kv_heads, hd, hd)
    return KVCache(
        k=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        v=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
    )


def attn_prefill(p, cfg, x, positions, cache, compute_dtype=None):
    q, k, v = _project_qkv(p, cfg, x, positions, compute_dtype)
    if cfg.attention_backend == "linear":
        o, cache = la_attention_prefill(q, k, v, _la_cfg(cfg), state=cache)
    else:
        n = k.shape[2]
        cache = KVCache(
            k=jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            v=jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)))
        o = softmax_chunked(q, k, v, causal=True)
    return dense(p["wo"], _merge_heads(o), compute_dtype), cache


def attn_decode(p, cfg, x, position, cache, compute_dtype=None):
    """x: (B, 1, C); position: (B, 1) absolute position of the new token."""
    q, k, v = _project_qkv(p, cfg, x, position, compute_dtype)
    if cfg.attention_backend == "linear":
        cache, o = la_attention_decode(
            cache, q[:, :, 0], k[:, :, 0], v[:, :, 0], _la_cfg(cfg))
        o = o[:, :, None]  # (B, H, 1, D)
    else:
        pos = position[0, 0]
        cache = KVCache(
            k=jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, pos, 0)),
            v=jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, pos, 0)))
        klen = pos + 1
        b, hkv, s, hd = cache.k.shape
        mask_j = jax.lax.broadcasted_iota(jnp.int32, (s,), 0) < klen
        g = cfg.num_heads // hkv
        qg = q.reshape(b, hkv, g, 1, hd).astype(F32)
        s_ = jnp.einsum("bhgid,bhjd->bhgij", qg, cache.k.astype(F32),
                        preferred_element_type=F32) / hd ** 0.5
        s_ = jnp.where(mask_j[None, None, None, None, :], s_, -1e30)
        pmat = jax.nn.softmax(s_, axis=-1)
        o = jnp.einsum("bhgij,bhjd->bhgid", pmat, cache.v.astype(F32),
                       preferred_element_type=F32)
        o = o.reshape(b, cfg.num_heads, 1, hd).astype(x.dtype)
    return dense(p["wo"], _merge_heads(o), compute_dtype), cache


# ---------------------------------------------------------------------------
# Cross-attention serving state (whisper decode): LA state over encoder
# ---------------------------------------------------------------------------

class CrossState(NamedTuple):
    s: jnp.ndarray  # (B, Hkv, D, D+1) — precomputed sum_j k_j (x) [v_j, 1]
    p: jnp.ndarray  # (B, Hkv, D+1)


def cross_precompute(p, cfg, ctx, compute_dtype=None) -> CrossState:
    """Precompute the LA cross-attention state from encoder output once."""
    hd = cfg.resolved_head_dim
    k = _split_heads(dense(p["wk"], ctx, compute_dtype), cfg.num_kv_heads, hd)
    v = _split_heads(dense(p["wv"], ctx, compute_dtype), cfg.num_kv_heads, hd)
    if cfg.la.normalize_qk:
        k = l2_normalize(k)
    vaug = jnp.concatenate(
        [v.astype(F32), jnp.ones(v.shape[:-1] + (1,), F32)], -1)
    s = jnp.einsum("bhjd,bhje->bhde", k.astype(F32), vaug,
                   preferred_element_type=F32)
    return CrossState(s=s, p=vaug.sum(axis=-2))


def cross_decode(p, cfg, x, state: CrossState, compute_dtype=None):
    """One-token cross-attention readout against the precomputed state."""
    hd = cfg.resolved_head_dim
    b = x.shape[0]
    q = _split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
    if cfg.la.normalize_qk:
        q = l2_normalize(q)
    hkv = state.s.shape[1]
    g = cfg.num_heads // hkv
    qg = q[:, :, 0].reshape(b, hkv, g, hd).astype(F32)
    la = cfg.la
    f = (la.a * state.p[:, :, None, :]
         + la.b * jnp.einsum("bhgd,bhde->bhge", qg, state.s,
                             preferred_element_type=F32))
    o = f[..., :hd] / f[..., hd:]
    o = o.reshape(b, cfg.num_heads, 1, hd).astype(x.dtype)
    return dense(p["wo"], _merge_heads(o), compute_dtype)
