"""Minimal functional layer library (no external NN framework).

Params are plain nested dicts of jnp arrays; every layer is an
(init, apply) pair.  Matmuls run in the config's compute dtype with f32
accumulation; norms always compute in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import BATCH, MODEL, constrain

F32 = jnp.float32


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               dtype=F32, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), F32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, compute_dtype=None, gather_weight: bool = False):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    if gather_weight:
        # FSDP semantics: un-shard the weight's data (FSDP) dim at the
        # point of use — the partitioner otherwise all-gathers the much
        # larger ACTIVATIONS over the contracting dim (observed on the
        # mamba2 in_proj).  Opt-in per call site: replicating weights is
        # a LOSS where the activation path was already collective-free.
        w = constrain(w, None, MODEL)
    y = jnp.einsum("...i,io->...o", x, w)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm", dtype=F32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(F32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(F32) + p["bias"].astype(F32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(F32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str = "swiglu", dtype=F32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi": dense_init(k1, d_model, d_ff, dtype=dtype),
            "wg": dense_init(k2, d_model, d_ff, dtype=dtype),
            "wo": dense_init(k3, d_ff, d_model, dtype=dtype),
        }
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype=dtype),
        "wo": dense_init(k2, d_ff, d_model, dtype=dtype),
    }


def mlp_apply(p, x, act: str = "swiglu", compute_dtype=None):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x, compute_dtype)) * dense(
            p["wi"], x, compute_dtype)
    else:
        h = jax.nn.gelu(dense(p["wi"], x, compute_dtype))
    if h.ndim == 3:
        h = constrain(h, BATCH, None, MODEL)
    return dense(p["wo"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=F32):
    return {"table": (jax.random.normal(key, (vocab, d), F32) * 0.02
                      ).astype(dtype)}


def embed_lookup(p, tokens, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, tokens, axis=0)


def unembed(p, x, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
        x = x.astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x, t)


def sinusoid_positions(n: int, d: int, offset=0) -> jnp.ndarray:
    """Computed sinusoidal absolute position encodings (whisper-style)."""
    pos = jnp.arange(n, dtype=F32) + offset
    inv = jnp.exp(-jnp.arange(0, d, 2, dtype=F32) / d * jnp.log(10000.0))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
