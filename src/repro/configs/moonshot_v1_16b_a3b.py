"""moonshot-v1-16b-a3b [moe] — Moonlight-style: 64 experts top-6.

48L d_model=2048 16H (kv=16) d_ff=1408(expert) vocab=163840
[hf:moonshotai/Moonlight-16B-A3B]: 2 shared experts, first layer dense
(d_ff 11264), expert parallelism over the "model" mesh axis.
"""
from repro.configs.base import LACfg, ModelConfig, MoECfg


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        attention_backend="linear", la=LACfg(),
        moe=MoECfg(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                   first_dense_layers=1, dense_d_ff=11264),
        rope_kind="standard", rope_theta=50000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        attention_backend="linear", la=LACfg(chunk=16),
        moe=MoECfg(num_experts=8, top_k=2, d_expert=32, num_shared=2,
                   first_dense_layers=1, dense_d_ff=128, capacity_factor=8.0),
        rope_kind="standard", remat=False, compute_dtype="float32",
    )
