"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (frontend stubbed).

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064  [arXiv:2409.12191]
The vision tower is a STUB: `input_specs()` provides the merged token
stream plus (3, B, N) t/h/w M-RoPE positions.
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, qkv_bias=True,
        attention_backend="linear", la=LACfg(),
        rope_kind="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, qkv_bias=True,
        attention_backend="linear", la=LACfg(chunk=16),
        rope_kind="mrope", mrope_sections=(2, 3, 3), rope_theta=1e6,
        frontend="vision", remat=False, compute_dtype="float32",
    )
