"""qwen2.5-3b [dense] — GQA (kv=2), QKV bias, tied embeddings.

36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936  [hf:Qwen/Qwen2.5]
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family="dense",
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936, qkv_bias=True,
        attention_backend="linear", la=LACfg(),
        rope_kind="standard", rope_theta=1e6, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, qkv_bias=True,
        attention_backend="linear", la=LACfg(chunk=16),
        rope_kind="standard", rope_theta=1e6, tie_embeddings=True,
        remat=False, compute_dtype="float32",
    )
