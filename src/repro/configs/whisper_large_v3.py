"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed.

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866  [arXiv:2212.04356]
The transformer backbone only: `input_specs()` feeds 1500 precomputed
frame embeddings; sinusoidal positions; layernorm + gelu (whisper-style).
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        mixer="attention", attention_backend="linear", la=LACfg(),
        mlp_act="gelu", norm="layernorm", rope_kind="sinusoid",
        encoder_layers=32, encoder_seq=1500, cross_attention=True,
        frontend="audio", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        mixer="attention", attention_backend="linear", la=LACfg(chunk=16),
        mlp_act="gelu", norm="layernorm", rope_kind="sinusoid",
        encoder_layers=2, encoder_seq=12, cross_attention=True,
        frontend="audio", tie_embeddings=True, remat=False, compute_dtype="float32",
    )
