"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242]: 81 mamba layers as 13 groups of 6 + 3 tail; ONE
shared attention+FFN block (reused weights) applied after each group,
with per-application serving caches.
"""
from repro.configs.base import LACfg, ModelConfig, SSMCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        mixer="mamba2", ssm=SSMCfg(state_dim=64, head_dim=64, expand=2),
        attention_backend="linear", la=LACfg(),
        hybrid_groups=13, hybrid_mamba_per_group=6, hybrid_tail=3,
        rope_kind="standard",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        mixer="mamba2", ssm=SSMCfg(state_dim=16, head_dim=32, expand=2),
        attention_backend="linear", la=LACfg(chunk=16),
        hybrid_groups=2, hybrid_mamba_per_group=2, hybrid_tail=1,
        rope_kind="standard", remat=False, compute_dtype="float32",
    )
