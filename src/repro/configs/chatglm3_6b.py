"""chatglm3-6b [dense] — 2d (half-dim) RoPE, GQA kv=2, QKV bias.

28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024  [arXiv:2406.12793]
ChatGLM applies rotary to half the head dim — modeled as partial RoPE 0.5.
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024, qkv_bias=True,
        attention_backend="linear", la=LACfg(),
        rope_kind="partial", rope_fraction=0.5,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, qkv_bias=True,
        attention_backend="linear", la=LACfg(chunk=16),
        rope_kind="partial", rope_fraction=0.5, remat=False, compute_dtype="float32",
    )
