"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).

64L d_model=2560 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]
Paper applicability (DESIGN.md §Arch-applicability): SSD *is* decay-gated
linear attention (paper Table 3, Mamba-2 row); implemented via the shared
chunked-scan machinery, not the paper's normalized un-decayed LA.
"""
from repro.configs.base import LACfg, ModelConfig, SSMCfg


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=80, num_kv_heads=80,
        d_ff=0, vocab_size=50280,
        mixer="mamba2", ssm=SSMCfg(state_dim=128, head_dim=64, expand=2),
        la=LACfg(), rope_kind="none", tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256,
        mixer="mamba2", ssm=SSMCfg(state_dim=16, head_dim=32, expand=2),
        la=LACfg(chunk=16), rope_kind="none", tie_embeddings=True,
        remat=False, compute_dtype="float32",
    )
