"""Architecture registry: --arch <id> resolution + input specs per shape.

`input_specs(cfg, shape)` returns jax.ShapeDtypeStruct stand-ins for every
model input of the given (architecture x shape) cell — weak-type-correct,
shardable, no device allocation — plus the step kind to lower
(train_step / prefill_step / serve_step).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen2.5-3b": "qwen2p5_3b",
    "stablelm-1.6b": "stablelm_1p6b",
    "chatglm3-6b": "chatglm3_6b",
    "granite-20b": "granite_20b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "pythia-1.4b": "pythia_1p4b",
}

ARCHS = [a for a in _MODULES if a != "pythia-1.4b"]  # the 10 assigned


def get_config(arch: str, smoke: bool = False, **kw) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return (mod.smoke if smoke else mod.full)(**kw)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


I32 = jnp.int32
F32 = jnp.float32


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Returns (specs: dict of ShapeDtypeStruct pytrees, step_kind: str)."""
    b, n = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    def batch_specs(seq_len):
        specs = {"tokens": sds((b, seq_len), I32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), F32)
        if cfg.rope_kind == "mrope":
            specs["positions"] = sds((3, b, seq_len), I32)
        return specs

    if shape.kind == "train":
        return batch_specs(n), "train"
    if shape.kind == "prefill":
        return batch_specs(n), "prefill"
    # decode: one new token against a cache holding seq_len of context
    from repro.models import model as mdl
    cache = jax.eval_shape(
        lambda: mdl.init_cache(cfg, b, n, jnp.bfloat16))
    return {"tokens": sds((b,), I32), "cache": cache}, "decode"
