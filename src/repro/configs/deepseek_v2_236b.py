"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 160 routed experts top-6.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400  [arXiv:2405.04434]
2 shared + 160 routed experts; first layer dense (d_ff 12288); MLA
rope/nope head split kept, attention computed with the paper's linear
backend after per-head decompression (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import LACfg, MLACfg, ModelConfig, MoECfg


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        d_ff=1536, vocab_size=102400,
        mixer="mla", attention_backend="linear", la=LACfg(),
        mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                   nope_head_dim=128, v_head_dim=128),
        moe=MoECfg(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                   first_dense_layers=1, dense_d_ff=12288),
        rope_kind="standard",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        mixer="mla", attention_backend="linear", la=LACfg(chunk=16),
        mla=MLACfg(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                   nope_head_dim=16, v_head_dim=16),
        moe=MoECfg(num_experts=8, top_k=2, d_expert=32, num_shared=2,
                   first_dense_layers=1, dense_d_ff=128, capacity_factor=8.0),
        rope_kind="standard", remat=False, compute_dtype="float32",
    )
