"""stablelm-1.6b [dense] — partial RoPE (25%), layernorm.

24L d_model=2048 32H (kv=32) d_ff=5632 vocab=100352
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        attention_backend="linear", la=LACfg(),
        norm="layernorm", rope_kind="partial", rope_fraction=0.25,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        attention_backend="linear", la=LACfg(chunk=16),
        norm="layernorm", rope_kind="partial", rope_fraction=0.25,
        remat=False, compute_dtype="float32",
    )
