"""Config schema for models, shapes, meshes, and training."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LACfg:
    """Paper's linear-attention kernel f(x) = a + b x (§2.2, §3.3).

    The SINGLE kernel-hyperparameter schema: every mixer backend reads
    its chunk size and kernel-impl name from here (there is no second,
    kernel-local config class), and `mixers.get_backend` validates the
    impl name against the KernelImpl registry at resolution time.
    """

    a: float = 1.0
    b: float = 1.0
    normalize_qk: bool = True
    # 512 tokens/chunk: +3% intra-chunk flops vs 128 but 4x fewer scan
    # iterations -> -20% HBM traffic on train cells (EXPERIMENTS §Perf)
    chunk: int = 512
    backend: str = "auto"  # auto | xla | pallas | pallas_interpret | ref
    # paper §2.2: (a, b) as LEARNABLE per-layer parameters instead of
    # the fixed Taylor coefficients (1, 1)
    learnable_coeffs: bool = False
    # route decode through the fused single-kernel step families
    # (kernels/decode_fused.py) on backends that declare
    # supports_fused_decode; False pins the legacy unfused composition
    # (the fused families' xla impls are that composition, so on xla
    # the two settings are byte-identical — docs/fused_decode.md)
    fused_decode: bool = True


@dataclasses.dataclass(frozen=True)
class PagingCfg:
    """Paged-KV serving cache (vLLM-style block pool; docs/paged_kv.md).

    When set on a softmax-backend config, the decode cache becomes a
    preallocated arena of `num_pages` fixed-size KV blocks per layer and
    requests address it through per-slot page tables instead of owning a
    contiguous max_len region.  `num_pages` counts TOTAL arena pages,
    including the one page the serving engine reserves as a write sink
    for retired slots (so num_pages - 1 are allocatable).
    """

    page_size: int = 16    # tokens per KV block
    num_pages: int = 0     # total arena pages (engine reserves one)


@dataclasses.dataclass(frozen=True)
class TuneCfg:
    """Kernel autotuning opt-in (docs/autotuning.md).

    When enabled, the launchers install the tuning cache at `cache_path`
    into kernel dispatch (repro.tune.activate_from_cfg): every
    KernelImpl wrapper then resolves its tile sizes — chunk, block_q/k,
    pages_per_block — from swept winners instead of the static
    kernels/defaults.py table.  A missing/empty cache file keeps
    dispatch byte-identical to the untuned defaults.
    """

    enabled: bool = False
    cache_path: str = "artifacts/tune_cache.json"


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0
    dense_d_ff: int = 0            # FFN width of the first dense layer(s)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    # beyond-paper: the paper's analytic-backward discipline applied to
    # the decay-gated (Mamba-2) mixer — O(N D) residuals instead of
    # autodiff's stacked chunk intermediates (see core/ssd.py)
    analytic_bwd: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    # ---- token mixer
    mixer: str = "attention"       # attention | mla | mamba2
    attention_backend: str = "linear"  # linear (paper) | softmax (baseline)
    la: LACfg = LACfg()
    # paged-KV serving cache (softmax backend only; set by the serving
    # engine's --page-size/--num-pages, never by model presets)
    paging: Optional[PagingCfg] = None
    # kernel autotuning opt-in (set by the launchers' --autotune flag,
    # never by model presets; None = untuned defaults)
    tune: Optional[TuneCfg] = None
    qkv_bias: bool = False
    # ---- block
    mlp_act: str = "swiglu"        # swiglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    parallel_residual: bool = False
    # ---- positions
    rope_kind: str = "standard"    # standard | partial | mrope | none | sinusoid
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    tie_embeddings: bool = False
    # ---- family extensions
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): groups x [mamba_per_group mamba layers + 1 shared
    # attention block (weights reused)] + tail mamba layers
    hybrid_groups: int = 0
    hybrid_mamba_per_group: int = 0
    hybrid_tail: int = 0
    # enc-dec (whisper): encoder layer count and fixed frame count
    encoder_layers: int = 0
    encoder_seq: int = 0
    cross_attention: bool = False
    frontend: str = "none"         # none | audio | vision (stubs)
    # ---- numerics / structure
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    logit_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Approximate total parameter count (for 6ND roofline math)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.mixer == "attention":
            per_layer += d * hd * (self.num_heads + 2 * self.num_kv_heads)
            per_layer += self.num_heads * hd * d
        elif self.mixer == "mla":
            m = self.mla
            per_layer += d * m.q_lora_rank
            per_layer += m.q_lora_rank * self.num_heads * (
                m.nope_head_dim + m.rope_head_dim)
            per_layer += d * (m.kv_lora_rank + m.rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (
                m.nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        elif self.mixer == "mamba2":
            s = self.ssm
            d_in = s.expand * d
            conv_ch = d_in + 2 * s.state_dim
            nheads = d_in // s.head_dim
            per_layer += d * (2 * d_in + 2 * s.state_dim + nheads)
            per_layer += conv_ch * s.conv_width
            per_layer += d_in * d
        mult = 3 if self.mlp_act == "swiglu" else 2
        if self.moe is not None:
            moe_ffn = 3 * self.moe.d_expert * d
            per_layer += (self.moe.num_experts * moe_ffn
                          + self.moe.num_shared * moe_ffn
                          + d * self.moe.num_experts)
        elif self.mixer != "mamba2":  # mamba blocks carry no FFN
            per_layer += mult * d * self.d_ff
        total = emb + self.num_layers * per_layer
        if self.moe is not None and self.moe.first_dense_layers:
            # first dense layer(s): swap the MoE FFN for a dense one
            moe_ffn = 3 * self.moe.d_expert * d
            per_moe = ((self.moe.num_experts + self.moe.num_shared)
                       * moe_ffn + d * self.moe.num_experts)
            dense_ff = mult * d * (self.moe.dense_d_ff or self.d_ff)
            total += self.moe.first_dense_layers * (dense_ff - per_moe)
        if self.family == "hybrid":
            # ONE shared attention+FFN block (reused weights)
            shared = (d * hd * (self.num_heads + 2 * self.num_kv_heads)
                      + self.num_heads * hd * d + mult * d * self.d_ff)
            total += shared
        if self.encoder_layers:
            # encoder blocks + decoder cross-attention
            enc_attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
            mult = 3 if self.mlp_act == "swiglu" else 2
            total += self.encoder_layers * (enc_attn + mult * d * self.d_ff)
            total += self.num_layers * enc_attn  # cross attn in decoder
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        moe_ffn = 3 * self.moe.d_expert * d
        inactive = (self.moe.num_experts - self.moe.top_k) * moe_ffn
        n_moe_layers = self.num_layers - self.moe.first_dense_layers
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3       # paper §5.2
    min_learning_rate: float = 5e-5
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0               # 0 = no gradient accumulation
    zero1: bool = True                # shard optimizer state over data axis
    grad_compression: str = "none"    # none | int8
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "checkpoints"
    straggler_threshold: float = 3.0  # x median step time
