"""granite-20b [dense] — llama-arch code model, MQA (kv=1).

52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152  [arXiv:2405.04324]
"""
from repro.configs.base import LACfg, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense",
        num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        attention_backend="linear", la=LACfg(),
        rope_kind="standard",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=256,
        attention_backend="linear", la=LACfg(chunk=16),
        rope_kind="standard", remat=False, compute_dtype="float32",
    )
