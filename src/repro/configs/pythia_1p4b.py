"""pythia-1.4b — the paper's own end-to-end LLM (§5.2).

24L d_model=2048 16H d_ff=8192 vocab=50304, parallel residual, partial
RoPE 0.25, layernorm (Biderman et al. 2023).  Trained in the paper on
Wiki-40B at N=8192 with linear vs regular attention.
"""
from repro.configs.base import LACfg, ModelConfig


def full(attention_backend: str = "linear") -> ModelConfig:
    return ModelConfig(
        name="pythia-1.4b", family="dense",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304,
        attention_backend=attention_backend, la=LACfg(),
        mlp_act="gelu", norm="layernorm", parallel_residual=True,
        rope_kind="partial", rope_fraction=0.25,
    )


def smoke(attention_backend: str = "linear") -> ModelConfig:
    return ModelConfig(
        name="pythia-1.4b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=256,
        attention_backend=attention_backend, la=LACfg(chunk=16),
        mlp_act="gelu", norm="layernorm", parallel_residual=True,
        rope_kind="partial", rope_fraction=0.25, remat=False, compute_dtype="float32",
    )
