"""train_step / prefill_step / serve_step builders.

build_train_step returns a pure (params, opt_state, batch, [err]) ->
(params, opt_state, metrics, [err]) function ready for jax.jit with the
shardings from distributed/.  Features:

  * microbatched gradient accumulation (lax.scan over microbatches —
    XLA's latency-hiding scheduler overlaps the per-microbatch grad
    all-reduce with the next microbatch's compute on TPU);
  * optional int8 gradient compression with error feedback on the DP
    all-reduce (distributed/compression.py) via an explicit psum form;
  * mixed precision: bf16 compute, f32 master params/moments handled by
    the model layer + AdamW.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.models import model as mdl
from repro.optim import adamw, schedules

F32 = jnp.float32


def make_loss(cfg):
    def loss(params, batch):
        return mdl.loss_fn(params, cfg, batch)
    return loss


def _microbatches(batch, n: int):
    """Split batch dim into (n, b/n, ...) for scan."""
    def split(x, bdim):
        b = x.shape[bdim]
        shape = x.shape[:bdim] + (n, b // n) + x.shape[bdim + 1:]
        return jnp.moveaxis(x.reshape(shape), bdim, 0)
    return {k: split(v, 1 if k == "positions" else 0)
            for k, v in batch.items()}


def build_train_step(cfg, train_cfg):
    """Returns step(params, opt_state, batch, step_idx) -> (...)."""
    loss = make_loss(cfg)

    def lr_at(step_idx):
        return schedules.cosine_warmup_decay(
            step_idx, max_lr=train_cfg.learning_rate,
            min_lr=train_cfg.min_learning_rate,
            warmup_steps=train_cfg.warmup_steps,
            total_steps=train_cfg.total_steps)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def compute_grads(params, batch):
        if train_cfg.microbatch and train_cfg.microbatch > 1:
            n = train_cfg.microbatch
            mb = _microbatches(batch, n)

            def body(acc, mbatch):
                (l, aux), g = grad_fn(params, mbatch)
                acc = jax.tree.map(lambda a, b: a + b.astype(F32), acc, g)
                return acc, (l, aux)

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            gsum, (losses, auxes) = jax.lax.scan(body, zero, mb)
            grads = jax.tree.map(lambda g: g / n, gsum)
            metrics = {"loss": losses.mean(),
                       "ce": auxes["ce"].mean(), "aux": auxes["aux"].mean()}
        else:
            (l, aux), grads = grad_fn(params, batch)
            metrics = {"loss": l, **aux}
        return grads, metrics

    def step(params, opt_state, batch, step_idx):
        grads, metrics = compute_grads(params, batch)
        lr = lr_at(step_idx)
        params, opt_state, om = adamw.apply(
            params, grads, opt_state, lr=lr, beta1=train_cfg.beta1,
            beta2=train_cfg.beta2, weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip)
        metrics.update(om)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return step


def build_compressed_train_step(cfg, train_cfg, axis_name: str = "data"):
    """Explicit-DP variant with int8 grad all-reduce + error feedback.

    Meant to be shard_map'd over the DP axis (per-device batch in, psum
    inside).  Carries the error-feedback pytree in the train state.
    """
    loss = make_loss(cfg)
    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(params, opt_state, err, batch, step_idx):
        (l, aux), grads = grad_fn(params, batch)
        grads, err = compression.compressed_psum(grads, err, axis_name)
        lr = schedules.cosine_warmup_decay(
            step_idx, max_lr=train_cfg.learning_rate,
            min_lr=train_cfg.min_learning_rate,
            warmup_steps=train_cfg.warmup_steps,
            total_steps=train_cfg.total_steps)
        params, opt_state, om = adamw.apply(
            params, grads, opt_state, lr=lr, beta1=train_cfg.beta1,
            beta2=train_cfg.beta2, weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip)
        metrics = {"loss": jax.lax.pmean(l, axis_name), **om}
        return params, opt_state, err, metrics

    return step


def build_prefill_step(cfg, window: int | None = None):
    """Prefill step; with `window`, the prompt is fed window-by-window
    carrying the recurrent state (chunked prefill) — peak activation
    memory drops ~N/window-fold, exact for every recurrent-state mixer
    (LA / SSD / hybrid).  Whisper stays single-shot (its cross-attention
    state is precomputed from the encoder, not accumulated)."""
    def prefill_step(params, batch):
        b, n = batch["tokens"].shape
        cache = mdl.init_cache(cfg, b, n)
        if window is None or n <= window or cfg.family == "encdec" \
                or n % window != 0:
            return mdl.prefill(params, cfg, batch, cache)
        t = n // window
        toks = batch["tokens"].reshape(b, t, window).transpose(1, 0, 2)
        xs = {"tokens": toks}
        if "positions" in batch:
            xs["positions"] = batch["positions"].reshape(
                3, b, t, window).transpose(2, 0, 1, 3)

        def body(cache, w):
            logits, cache = mdl.prefill(params, cfg, w, cache)
            return cache, logits

        cache, logits_all = jax.lax.scan(body, cache, xs)
        return logits_all[-1], cache
    return prefill_step


def build_serve_step(cfg):
    """One-token decode against an existing cache (paper's O(D^2)/token
    deployment path for the linear backend)."""
    def serve_step(params, cache, tokens):
        return mdl.decode_step(params, cfg, cache, tokens)
    return serve_step
