"""Trainer: the fault-tolerant outer loop.

Responsibilities (the parts XLA cannot do):
  * checkpoint/restart — periodic async sharded checkpoints; on any step
    failure, reload the last checkpoint and replay the data stream from
    the same batch index (the pipeline is index-deterministic);
  * straggler mitigation — per-step wall time tracked against a running
    median; a step slower than `straggler_threshold x median` is logged
    and counted; persistent stragglers trigger an elastic re-mesh
    request (launch/elastic.py decides);
  * bounded retry — `max_retries` consecutive failures abort the job
    rather than loop forever.
"""
from __future__ import annotations

import logging
import statistics
from repro.tune.timer import now

import jax
import numpy as np

from repro.checkpoint import store
from repro.optim import adamw
from repro.train.step import build_train_step

log = logging.getLogger("repro.train")


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.times: list[float] = []
        self.window = window
        self.flagged = 0

    def record(self, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.window:])
            is_straggler = dt > self.threshold * med
        self.times.append(dt)
        if is_straggler:
            self.flagged += 1
        return is_straggler

    @property
    def needs_remesh(self) -> bool:
        # persistent stragglers: >10% of recent steps flagged
        recent = min(len(self.times), self.window)
        return recent >= 20 and self.flagged > 0.1 * recent


class Trainer:
    def __init__(self, cfg, train_cfg, params, data_it, *,
                 step_fn=None, checkpoint_tree_extra=None,
                 max_retries: int = 3):
        self.cfg = cfg
        self.tc = train_cfg
        self.params = params
        self.opt_state = adamw.init(params)
        self.data_it = data_it
        self.step_fn = step_fn or jax.jit(build_train_step(cfg, train_cfg))
        self.monitor = StragglerMonitor(train_cfg.straggler_threshold)
        self.max_retries = max_retries
        self.step_idx = 0
        self.history: list[dict] = []
        self._pending_save = None

    # -- checkpointing ------------------------------------------------
    def _tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, blocking: bool = False):
        self._wait_save()
        out = store.save(self.tc.checkpoint_dir, self._tree(),
                         self.step_idx, blocking=blocking)
        if not blocking:
            self._pending_save = out[1]

    def _wait_save(self):
        if self._pending_save is not None:
            self._pending_save.join()
            self._pending_save = None

    def try_restore(self) -> bool:
        step = store.latest_step(self.tc.checkpoint_dir)
        if step is None:
            return False
        tree, step = store.restore(self.tc.checkpoint_dir, self._tree(),
                                   step)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step_idx = step
        log.info("restored checkpoint at step %d", step)
        return True

    # -- the loop -----------------------------------------------------
    def run(self, num_steps: int, fail_injector=None):
        """Train for num_steps (from the current step_idx)."""
        retries = 0
        target = self.step_idx + num_steps
        while self.step_idx < target:
            batch_np = self.data_it.batch_at(self.step_idx)
            batch = {"tokens": batch_np} if isinstance(batch_np, np.ndarray) \
                else batch_np
            t0 = now()
            try:
                if fail_injector is not None:
                    fail_injector(self.step_idx)
                out = self.step_fn(self.params, self.opt_state, batch,
                                   self.step_idx)
                params, opt_state, metrics = out
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(
                        f"non-finite loss {loss} at step {self.step_idx}")
                self.params, self.opt_state = params, opt_state
            except Exception as e:  # noqa: BLE001 — node/step failure path
                retries += 1
                log.warning("step %d failed (%s); retry %d/%d from last "
                            "checkpoint", self.step_idx, e, retries,
                            self.max_retries)
                if retries > self.max_retries:
                    raise
                if not self.try_restore():
                    # no checkpoint yet: retry the same step fresh
                    continue
                continue
            retries = 0
            dt = now() - t0
            slow = self.monitor.record(dt)
            rec = {"step": self.step_idx, "loss": loss, "dt": dt,
                   "straggler": slow}
            self.history.append(rec)
            self.step_idx += 1
            if self.tc.checkpoint_every and \
                    self.step_idx % self.tc.checkpoint_every == 0:
                self.save(blocking=False)
        self._wait_save()
        return self.history
