"""Chunked-scan formulation of the paper's linear attention — XLA path.

This is the TPU-native adaptation of the paper's prefix-sum factorization
(Eqs. 5-9, 19-21).  The sequence is processed in MXU-friendly chunks of C
tokens; the paper's "repeated computation patterns" x^(1), x^(2), y^(1),
y^(2) collapse into a single carried state by augmenting V with a ones
column:

    V' = [V, 1]                               (C, D+1)
    S  = sum_{n < chunk} k_n (x) V'_n          (D, D+1)   ["Linear term" state]
    P  = sum_{n < chunk} V'_n                  (D+1,)     ["Constant term" state]
    F' = a (1 P^T + cumsum V') + b (Q S + tril(Q K^T) V')
    O  = F'[:, :D] / F'[:, D]                 (numerator / g)

The backward pass implements the paper's analytic gradient (Eqs. 19-21)
from residuals {Q, K, V, O, g} only — O(N D) memory — with one forward
chunk scan (grad Q; the alpha^Q/beta^Q recurrences) and one reverse chunk
scan (grad K and grad V fused; the alpha^K/beta^K/alpha^V/beta^V
recurrences), each carrying a single augmented (D+1)-state.

All matmuls accumulate in f32 (`preferred_element_type`); inputs may be
bf16.  Grouped-query attention is supported natively: q is (B, H, N, D)
and k/v are (B, Hkv, N, D) with Hkv | H — the state is per KV head and
shared across the query group, so no KV repetition is materialized.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.numerics import safe_div

F32 = jnp.float32


class LAState(NamedTuple):
    """Recurrent linear-attention state (decode cache; constant in N).

    s: (B, Hkv, Dk, Dv+1) — sum of k (x) [v, 1]
    p: (B, Hkv, Dv+1)     — sum of [v, 1] (last component = token count)
    """

    s: jnp.ndarray
    p: jnp.ndarray


def init_state(batch: int, num_kv_heads: int, dk: int, dv: int | None = None,
               dtype=jnp.float32) -> LAState:
    dv = dk if dv is None else dv
    return LAState(
        s=jnp.zeros((batch, num_kv_heads, dk, dv + 1), dtype),
        p=jnp.zeros((batch, num_kv_heads, dv + 1), dtype),
    )


def _group(q: jnp.ndarray, num_kv_heads: int) -> jnp.ndarray:
    """(B, H, N, D) -> (B, Hkv, G, N, D)."""
    b, h, n, d = q.shape
    assert h % num_kv_heads == 0, (h, num_kv_heads)
    return q.reshape(b, num_kv_heads, h // num_kv_heads, n, d)


def _pad_to(x: jnp.ndarray, n: int, axis: int) -> jnp.ndarray:
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _chunks(x: jnp.ndarray, c: int, axis: int) -> jnp.ndarray:
    """Split axis `axis` of length T*c into leading (T, ..., c, ...)."""
    t = x.shape[axis] // c
    new_shape = x.shape[:axis] + (t, c) + x.shape[axis + 1:]
    x = x.reshape(new_shape)
    return jnp.moveaxis(x, axis, 0)


# ---------------------------------------------------------------------------
# Forward (causal)
# ---------------------------------------------------------------------------

def la_fwd_chunked(q, k, v, a: float, b: float, chunk: int = 512,
                   state: LAState | None = None):
    """Causal normalized linear attention, chunked scan.

    Returns (o, g, final_state):
      o: (B, H, N, D) in q.dtype, g: (B, H, N) f32 normalizer,
      final_state: LAState (f32) — feeds decode.
    """
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    out_dtype = q.dtype
    c = min(chunk, n)
    n_pad = -(-n // c) * c

    qg = _group(_pad_to(q, n_pad, 2), hkv)
    kp = _pad_to(k, n_pad, 2)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    # ones column appended BEFORE padding so padded rows contribute nothing
    # to the carried state (count column included).
    vaug = _pad_to(jnp.concatenate([v, ones], axis=-1), n_pad, 2)

    q_c = _chunks(qg, c, 3)      # (T,B,Hkv,G,C,D)
    k_c = _chunks(kp, c, 2)      # (T,B,Hkv,C,D)
    va_c = _chunks(vaug, c, 2)   # (T,B,Hkv,C,D+1)

    tril = jnp.tril(jnp.ones((c, c), F32))
    if state is None:
        state = init_state(bsz, hkv, dk, dv)
    a32, b32 = jnp.asarray(a, F32), jnp.asarray(b, F32)

    def step(carry, inp):
        s, p = carry
        qc, kc, vac = inp
        att = a32 + b32 * jnp.einsum("bhgid,bhjd->bhgij", qc, kc,
                                     preferred_element_type=F32)
        att = att * tril
        f_intra = jnp.einsum("bhgij,bhje->bhgie", att, vac,
                             preferred_element_type=F32)
        f_inter = (a32 * p[:, :, None, None, :]
                   + b32 * jnp.einsum("bhgid,bhde->bhgie", qc, s,
                                      preferred_element_type=F32))
        f = f_intra + f_inter
        s = s + jnp.einsum("bhjd,bhje->bhde", kc, vac,
                           preferred_element_type=F32)
        p = p + jnp.sum(vac.astype(F32), axis=-2)
        return (s, p), f

    (s_f, p_f), f_all = jax.lax.scan(step, (state.s, state.p),
                                     (q_c, k_c, va_c))
    # (T,B,Hkv,G,C,Dv+1) -> (B,H,Np,Dv+1)
    f_all = jnp.moveaxis(f_all, 0, 3).reshape(bsz, h, n_pad, dv + 1)
    f_all = f_all[:, :, :n]
    g = f_all[..., dv]
    o = safe_div(f_all[..., :dv], g[..., None]).astype(out_dtype)
    return o, g, LAState(s_f, p_f)


# ---------------------------------------------------------------------------
# Backward (causal) — paper Eqs. 19-21, chunked
# ---------------------------------------------------------------------------

def la_bwd_chunked(q, k, v, o, g, omega, a: float, b: float,
                   chunk: int = 512):
    """Analytic gradient from residuals {q,k,v,o,g} and upstream grad omega.

    Returns (dq, dk, dv) in the respective input dtypes.
    """
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    a32, b32 = jnp.asarray(a, F32), jnp.asarray(b, F32)

    # Ω̂ = Ω / g  and  h_i = o_i · Ω̂_i   (paper Eq. 20)
    om_hat = safe_div(omega.astype(F32), g[..., None])
    h_vec = jnp.sum(o.astype(F32) * om_hat, axis=-1)  # (B,H,N)

    om_hat = _group(_pad_to(om_hat, n_pad, 2), hkv)
    h_g = _group(_pad_to(h_vec[..., None], n_pad, 2), hkv)
    qg = _group(_pad_to(q, n_pad, 2), hkv)
    kp = _pad_to(k, n_pad, 2)
    vp = _pad_to(v, n_pad, 2)
    ones = jnp.ones(vp.shape[:-1] + (1,), F32)
    vaug = jnp.concatenate([vp.astype(F32), ones], -1)       # [v, 1]
    vneg = jnp.concatenate([vp.astype(F32), -ones], -1)      # [v, -1]
    qaug = jnp.concatenate([qg.astype(F32),
                            jnp.ones(qg.shape[:-1] + (1,), F32)], -1)

    q_c = _chunks(qg, c, 3)
    qa_c = _chunks(qaug, c, 3)
    k_c = _chunks(kp, c, 2)
    va_c = _chunks(vaug, c, 2)
    vn_c = _chunks(vneg, c, 2)
    omh_c = _chunks(om_hat, c, 3)
    h_c = _chunks(h_g, c, 3)

    tril = jnp.tril(jnp.ones((c, c), F32))

    # ---- grad Q: forward scan, carry A = sum k (x) [v,1]  (alpha^Q/beta^Q)
    def step_q(carry, inp):
        a_st = carry
        qc, kc, vac, omc, hc = inp
        gmat = jnp.concatenate([omc, -hc], axis=-1)  # [Ω̂, -h]
        sc = jnp.einsum("bhgie,bhje->bhgij", gmat, vac,
                        preferred_element_type=F32) * tril
        dq_intra = jnp.einsum("bhgij,bhjd->bhgid", sc, kc,
                              preferred_element_type=F32)
        dq_inter = jnp.einsum("bhgie,bhde->bhgid", gmat, a_st,
                              preferred_element_type=F32)
        a_st = a_st + jnp.einsum("bhjd,bhje->bhde", kc, vac,
                                 preferred_element_type=F32)
        return a_st, b32 * (dq_intra + dq_inter)

    a0 = jnp.zeros((bsz, hkv, dk, dv + 1), F32)
    _, dq_all = jax.lax.scan(step_q, a0, (q_c, k_c, va_c, omh_c, h_c))

    # ---- grad K / grad V: reverse scan, carry U = suffix sum q' (x) [Ω̂, h]
    def step_kv(carry, inp):
        u = carry  # (B,Hkv,D+1,D+1)
        qc, qac, kc, vnc, omc, hc = inp
        g2 = jnp.concatenate([omc, hc], axis=-1)  # [Ω̂, +h]
        # dK intra: sum_{i>=p} q_i (Ω̂_i·v_p - h_i)
        sc = jnp.einsum("bhgie,bhpe->bhgip", g2, vnc,
                        preferred_element_type=F32) * tril
        dk_intra = jnp.einsum("bhgip,bhgid->bhpd", sc, qc,
                              preferred_element_type=F32)
        dk_inter = jnp.einsum("bhpe,bhde->bhpd", vnc, u[..., :dk, :],
                              preferred_element_type=F32)
        # dV intra: sum_{i>=p} (a + b q_i·k_p) Ω̂_i
        att = a32 + b32 * jnp.einsum("bhgid,bhpd->bhgip", qc, kc,
                                     preferred_element_type=F32)
        att = att * tril
        dv_intra = jnp.einsum("bhgip,bhgij->bhpj", att, omc,
                              preferred_element_type=F32)
        dv_inter = (b32 * jnp.einsum("bhpd,bhdj->bhpj", kc,
                                     u[..., :dk, :dv],
                                     preferred_element_type=F32)
                    + a32 * u[..., dk, :dv][:, :, None, :])
        u = u + jnp.einsum("bhgic,bhgie->bhce", qac, g2,
                           preferred_element_type=F32)
        return u, (b32 * (dk_intra + dk_inter), dv_intra + dv_inter)

    u0 = jnp.zeros((bsz, hkv, dk + 1, dv + 1), F32)
    _, (dk_all, dv_all) = jax.lax.scan(step_kv, u0,
                                       (q_c, qa_c, k_c, vn_c, omh_c, h_c),
                                       reverse=True)

    dq = jnp.moveaxis(dq_all, 0, 3).reshape(bsz, h, n_pad, dk)[:, :, :n]
    dk_o = jnp.moveaxis(dk_all, 0, 2).reshape(bsz, hkv, n_pad, dk)[:, :, :n]
    dv_o = jnp.moveaxis(dv_all, 0, 2).reshape(bsz, hkv, n_pad, dv)[:, :, :n]
    return dq.astype(q.dtype), dk_o.astype(k.dtype), dv_o.astype(v.dtype)


# ---------------------------------------------------------------------------
# Non-causal (paper Eq. 4, right) — cross-attention path
# ---------------------------------------------------------------------------

def la_noncausal(q, k, v, a: float, b: float):
    """Bidirectional normalized LA: O(N D^2) einsum chain, autodiff-safe.

    q: (B, H, Nq, D); k/v: (B, Hkv, Nk, D).  Intermediates are O(D^2 + ND),
    so autodiff already achieves the paper's memory bound here; no custom
    backward is needed.
    """
    bsz, h, nq, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    out_dtype = q.dtype
    qg = _group(q, hkv)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    vaug = jnp.concatenate([v, ones], -1)
    s = jnp.einsum("bhjd,bhje->bhde", k, vaug, preferred_element_type=F32)
    p = jnp.sum(vaug.astype(F32), axis=-2)  # (B,Hkv,D+1)
    f = (a * p[:, :, None, None, :]
         + b * jnp.einsum("bhgid,bhde->bhgie", qg, s,
                          preferred_element_type=F32))
    o = safe_div(f[..., :dv], f[..., dv:])
    return o.reshape(bsz, h, nq, dv).astype(out_dtype)


# ---------------------------------------------------------------------------
# Decode (serving): O(D^2) per token, state independent of context length
# ---------------------------------------------------------------------------

def la_decode_step(state: LAState, q, k, v, a: float, b: float):
    """One-token decode.  q: (B, H, D); k, v: (B, Hkv, D).

    Returns (new_state, o) with o: (B, H, D).  This is the paper's
    deployment story: constant-time, constant-memory generation.
    """
    bsz, h, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    kf, vf = k.astype(F32), v.astype(F32)
    vaug = jnp.concatenate([vf, jnp.ones((bsz, hkv, 1), F32)], -1)
    s = state.s + kf[..., :, None] * vaug[..., None, :]
    p = state.p + vaug
    qg = q.reshape(bsz, hkv, h // hkv, dk)
    f = (a * p[:, :, None, :]
         + b * jnp.einsum("bhgd,bhde->bhge", qg, s,
                          preferred_element_type=F32))
    o = safe_div(f[..., :dv], f[..., dv:])
    return LAState(s, p), o.reshape(bsz, h, dv).astype(q.dtype)
