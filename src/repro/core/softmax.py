"""Chunked online-softmax attention — XLA path of the softmax baseline.

This is the Regular-Attention baseline the paper compares against: the
lax.scan analogue of FlashAttention-2, O(N) memory on any backend.  The
Pallas TPU twin lives in `kernels.flash_attention`; both are registered
as `KernelImpl` entries of the "softmax" family in `kernels.ops`, and
both cover the full feature set — GQA without KV expansion, training
(autodiff through the scan here, flash v2's custom vjp there) and the
per-slot `q_offset` continuation-prefill mask below (scalar prefetch in
the flash kernel) — so impl choice is purely an execution decision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def softmax_chunked(q, k, v, *, causal: bool = True, chunk: int = 512,
                    q_offset=None):
    """q: (B,H,Nq,D); k,v: (B,Hkv,Nk,D).  Online-softmax over KV chunks.

    q_offset: optional (B,) int32 — PER-SEQUENCE global position of query
    0 (serving continuation prefill: each slot's prompt window sits at its
    own absolute offset inside a max_len KV cache, and attends to its
    cached prefix plus itself).  None keeps the training convention
    (query i is global position i + Nk - Nq, shared across the batch).
    """
    b, h, nq, d = q.shape
    dv = v.shape[-1]
    hkv, nk = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / d ** 0.5
    c = min(chunk, nk)
    t = -(-nk // c)
    nk_pad = t * c
    padw = [(0, 0), (0, 0), (0, nk_pad - nk), (0, 0)]
    kp, vp = jnp.pad(k, padw), jnp.pad(v, padw)
    k_c = jnp.moveaxis(kp.reshape(b, hkv, t, c, d), 2, 0)
    v_c = jnp.moveaxis(vp.reshape(b, hkv, t, c, dv), 2, 0)
    qg = q.reshape(b, hkv, g, nq, d).astype(F32)
    iq = jax.lax.broadcasted_iota(jnp.int32, (nq, c), 0)
    offs = nk - nq  # causal offset: query i is global position i + offs

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, ti = inp
        s = scale * jnp.einsum("bhgid,bhjd->bhgij", qg, kc.astype(F32),
                               preferred_element_type=F32)
        jk = ti * c + jax.lax.broadcasted_iota(jnp.int32, (nq, c), 1)
        mask = jk < nk  # padded keys never attend
        if causal and q_offset is None:
            mask = mask & (iq + offs >= jk)
        if causal and q_offset is not None:
            # per-sequence offsets: (B, nq, c) -> broadcast over (hkv, g)
            mask = (mask[None]
                    & (iq[None] + q_offset[:, None, None] >= jk[None]))
            mask = mask[:, None, None]
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        corr = jnp.exp(m - m_new)
        pmat = jnp.exp(s - m_new[..., None])
        l = corr * l + pmat.sum(-1)
        acc = corr[..., None] * acc + jnp.einsum(
            "bhgij,bhjd->bhgid", pmat, vc.astype(F32),
            preferred_element_type=F32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, nq), -1e30, F32)
    l0 = jnp.zeros((b, hkv, g, nq), F32)
    a0 = jnp.zeros((b, hkv, g, nq, dv), F32)
    if q_offset is None:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      (k_c, v_c, jnp.arange(t)))
    else:
        # serving continuation prefill: keys beyond the deepest slot's
        # causal frontier contribute exactly zero — bound the KV walk at
        # that chunk (dynamic trip count; this path is inference-only,
        # the q_offset=None training path keeps the differentiable scan)
        t_live = jnp.minimum(
            (jnp.max(q_offset) + nq + c - 1) // c, t).astype(jnp.int32)

        def body(ti, carry):
            kc = jax.lax.dynamic_index_in_dim(k_c, ti, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(v_c, ti, 0, keepdims=False)
            carry, _ = step(carry, (kc, vc, ti))
            return carry

        m, l, acc = jax.lax.fori_loop(0, t_live, body, (m0, l0, a0))
    o = acc / l[..., None]
    return o.reshape(b, h, nq, dv).astype(q.dtype)
