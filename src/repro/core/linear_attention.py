"""Module-level API for the paper's linear attention.

This is the composable entry point models use: it applies the paper's
q/k l2 normalization (Eq. 22), dispatches causal / non-causal paths, and
exposes prefill/decode for serving.  The heavy lifting lives in
`core.chunked` (XLA path) and `kernels.linear_attention` (Pallas path),
tied together by the custom-vjp wrapper in `kernels.ops`.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.chunked import LAState, init_state
from repro.core.numerics import l2_normalize
from repro.kernels import ops as _ops


@dataclasses.dataclass(frozen=True)
class LAConfig:
    """Linear-attention hyperparameters (paper §3-4)."""

    a: float = 1.0           # constant kernel coefficient; f(x) = a + b x
    b: float = 1.0
    normalize_qk: bool = True  # paper Eq. 22
    chunk: int = 128           # TPU chunk size (MXU-aligned)
    backend: str = "auto"      # auto | xla | pallas | pallas_interpret | ref


def la_attention(q, k, v, cfg: LAConfig = LAConfig(), *, causal: bool = True):
    """q: (B, H, N, D); k, v: (B, Hkv, N, D).  Returns (B, H, N, D)."""
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    if causal:
        return _ops.la_causal(q, k, v, cfg.a, cfg.b, cfg.chunk, cfg.backend)
    return _ops.la_noncausal(q, k, v, cfg.a, cfg.b)


def la_attention_prefill(q, k, v, cfg: LAConfig = LAConfig(),
                         state: LAState | None = None):
    """Serving prefill: returns (o, LAState) for subsequent decode."""
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    return _ops.la_prefill(q, k, v, cfg.a, cfg.b, cfg.chunk, state=state)


def la_attention_decode(state: LAState, q, k, v, cfg: LAConfig = LAConfig()):
    """Serving decode: one token.  q: (B, H, D); k, v: (B, Hkv, D).

    O(D^2) per token — context length only enters through the state.
    """
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    return _ops.la_decode_step(state, q, k, v, cfg.a, cfg.b)


__all__ = [
    "LAConfig", "LAState", "init_state",
    "la_attention", "la_attention_prefill", "la_attention_decode",
]
