"""Module-level API for the paper's linear attention.

This is the composable entry point the `linear` mixer backend uses: it
applies the paper's q/k l2 normalization (Eq. 22), dispatches causal /
non-causal paths, and exposes prefill/decode for serving.  The heavy
lifting lives in `core.chunked` (XLA path) and `kernels.linear_attention`
(Pallas path), tied together by the KernelImpl registry and custom-vjp
wrapper in `kernels.ops`.

Hyperparameters come as `configs.base.LACfg` — the single schema of
record (there is deliberately no second, kernel-local config class).
"""
from __future__ import annotations

from repro.configs.base import LACfg
from repro.core.chunked import LAState, init_state
from repro.core.numerics import l2_normalize
from repro.kernels import ops as _ops


def la_attention(q, k, v, cfg: LACfg = LACfg(), *, causal: bool = True):
    """q: (B, H, N, D); k, v: (B, Hkv, N, D).  Returns (B, H, N, D)."""
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    if causal:
        return _ops.la_causal(q, k, v, cfg.a, cfg.b, cfg.chunk, cfg.backend)
    return _ops.la_noncausal(q, k, v, cfg.a, cfg.b)


def la_attention_learnable(q, k, v, a, b, cfg: LACfg = LACfg()):
    """Causal LA with learnable scalar coefficients (paper §2.2).

    a, b: scalar jnp arrays (per-layer parameters); gradients flow to
    q, k, v, a and b through the analytic backward in kernels.ops.
    """
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    return _ops.la_causal_learnable(q, k, v, a, b, cfg.chunk, cfg.backend)


def la_attention_prefill(q, k, v, cfg: LACfg = LACfg(),
                         state: LAState | None = None):
    """Serving prefill: returns (o, LAState) for subsequent decode."""
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    return _ops.la_prefill(q, k, v, cfg.a, cfg.b, cfg.chunk, state=state)


def la_attention_decode(state: LAState, q, k, v, cfg: LACfg = LACfg()):
    """Serving decode: one token.  q: (B, H, D); k, v: (B, Hkv, D).

    O(D^2) per token — context length only enters through the state.
    cfg.fused_decode routes through the fused single-kernel step family
    (state update + q·S + normalizer divide in one Pallas kernel on the
    pallas impls); the normalization stays HERE so fused and unfused
    see identical q/k.
    """
    if cfg.normalize_qk:
        q, k = l2_normalize(q), l2_normalize(k)
    if cfg.fused_decode:
        return _ops.la_decode_step_fused(state, q, k, v, cfg.a, cfg.b,
                                         backend=cfg.backend)
    return _ops.la_decode_step(state, q, k, v, cfg.a, cfg.b)


__all__ = [
    "LACfg", "LAState", "init_state",
    "la_attention", "la_attention_learnable",
    "la_attention_prefill", "la_attention_decode",
]
