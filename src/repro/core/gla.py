"""Chunked decay-gated (GLA-style) normalized linear attention — XLA path.

The paper's chunked prefix-sum factorization (core/chunked.py) extended
with a learned per-KV-head, per-token decay gamma_t = exp(log_decay_t)
in (0, 1] multiplying the running KV state (Yang et al., "Gated Linear
Attention Transformers with Hardware-Efficient Training"; ROADMAP
"decay-gated LA"):

    S_t = gamma_t S_{t-1} + k_t (x) [v_t, 1]      (D, D+1)
    P_t = gamma_t P_{t-1} + [v_t, 1]              (D+1,)
    F_t = a P_t + b q_t S_t ;  o_t = F[:D] / F[D]

i.e. the attention weight of key n at query i is
M_in (a + b q_i.k_n) with M_in = prod_{m=n+1..i} gamma_m — the paper's
normalized f(x) = a + b x scores, decayed by the gate.  log_decay == 0
degenerates EXACTLY to the linear family (la_fwd_chunked), which is the
parity anchor the tests pin.

Decay algebra runs in log space: within a chunk the exponents are
differences of a monotone (non-increasing) cumsum, always <= 0, so every
exp() here is <= 1 and the scan is stable in f32.

The backward extends the paper's Eqs. 19-21 discipline to the gated
mixer with residuals {q, k, v, log_decay, o, g} — O(N D).  With
om_hat = omega / g,  h_i = o_i . om_hat_i and gmat = [om_hat, -h]:

    dq_i  = b S_i @ gmat_i                        (forward chunk scan)
    dk_n  = b U_n[:D] @ V'_n                      (reverse chunk scan,
    dV'_n = b U_n[:D]^T k_n + a U_n[D]             U = decayed qaug gmat^T)
    dcl_n = -V'_n . dV'_n                         (row term vanishes:
                                                   df_i . f_i == 0 under
                                                   the normalization)
    dld_t = sum_{n >= t} dcl_n                    (reverse cumsum)

Grouped-query attention is native: q is (B, H, N, D), k/v are
(B, Hkv, N, D) and log_decay is (B, Hkv, N) — the decayed state is per
KV head and shared across the query group, so the decay gate never
materializes an H-fold copy.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# the chunk/pad/group plumbing is identical to the ungated scan's —
# import it so a padding/convention fix there cannot miss this module
from repro.core.chunked import _chunks, _group, _pad_to
from repro.core.numerics import safe_div

F32 = jnp.float32


class GLAState(NamedTuple):
    """Decayed recurrent GLA state (decode cache; constant in N).

    Same shapes as the linear family's LAState — s: (B, Hkv, Dk, Dv+1),
    p: (B, Hkv, Dv+1) — but every accumulated term carries the decay
    from its token to the state's frontier.
    """

    s: jnp.ndarray
    p: jnp.ndarray


def init_gla_state(batch: int, num_kv_heads: int, dk: int,
                   dv: int | None = None, dtype=jnp.float32) -> GLAState:
    dv = dk if dv is None else dv
    return GLAState(
        s=jnp.zeros((batch, num_kv_heads, dk, dv + 1), dtype),
        p=jnp.zeros((batch, num_kv_heads, dv + 1), dtype),
    )


def _decay_mask(cl: jnp.ndarray, tril: jnp.ndarray) -> jnp.ndarray:
    """(..., C) cumulative log decay -> (..., C, C) M_in, n <= i else 0.

    The exponent is clamped at 0: above-diagonal differences are
    positive and would overflow under strong decay before the mask
    zeroes them."""
    diff = jnp.minimum(cl[..., :, None] - cl[..., None, :], 0.0)
    return jnp.where(tril, jnp.exp(diff), 0.0)


# ---------------------------------------------------------------------------
# Forward (causal)
# ---------------------------------------------------------------------------

def gla_fwd_chunked(q, k, v, log_decay, a: float, b: float,
                    chunk: int = 512, state: GLAState | None = None):
    """Causal decay-gated normalized linear attention, chunked scan.

    q: (B, H, N, Dk); k, v: (B, Hkv, N, D); log_decay: (B, Hkv, N) <= 0.
    Returns (o, g, final_state): o (B, H, N, Dv) in q.dtype, g (B, H, N)
    f32 normalizer, final_state GLAState (f32) — feeds decode.
    """
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    out_dtype = q.dtype
    c = min(chunk, n)
    n_pad = -(-n // c) * c

    qg = _group(_pad_to(q, n_pad, 2), hkv)
    kp = _pad_to(k, n_pad, 2)
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    # ones column appended BEFORE padding so padded rows contribute
    # nothing to the carried state; padded log_decay rows are 0 (no
    # decay), so padding never shrinks the carried state either
    vaug = _pad_to(jnp.concatenate([v, ones], axis=-1), n_pad, 2)
    ldp = _pad_to(log_decay.astype(F32), n_pad, 2)

    q_c = _chunks(qg, c, 3)      # (T,B,Hkv,G,C,Dk)
    k_c = _chunks(kp, c, 2)      # (T,B,Hkv,C,Dk)
    va_c = _chunks(vaug, c, 2)   # (T,B,Hkv,C,Dv+1)
    ld_c = _chunks(ldp, c, 2)    # (T,B,Hkv,C)

    tril = jnp.tril(jnp.ones((c, c), bool))
    if state is None:
        state = init_gla_state(bsz, hkv, dk, dv)
    a32, b32 = jnp.asarray(a, F32), jnp.asarray(b, F32)

    def step(carry, inp):
        s, p = carry
        qc, kc, vac, ld = inp
        cl = jnp.cumsum(ld, axis=-1)                 # (B,Hkv,C)
        total = cl[..., -1:]
        att = a32 + b32 * jnp.einsum("bhgid,bhjd->bhgij", qc, kc,
                                     preferred_element_type=F32)
        att = att * _decay_mask(cl, tril)[:, :, None]
        f_intra = jnp.einsum("bhgij,bhje->bhgie", att, vac,
                             preferred_element_type=F32)
        f_inter = jnp.exp(cl)[:, :, None, :, None] * (
            a32 * p[:, :, None, None, :]
            + b32 * jnp.einsum("bhgid,bhde->bhgie", qc, s,
                               preferred_element_type=F32))
        f = f_intra + f_inter
        vw = jnp.exp(total - cl)[..., None] * vac.astype(F32)
        s = (jnp.exp(total)[..., None] * s
             + jnp.einsum("bhjd,bhje->bhde", kc, vw,
                          preferred_element_type=F32))
        p = jnp.exp(total) * p + jnp.sum(vw, axis=-2)
        return (s, p), f

    (s_f, p_f), f_all = jax.lax.scan(step, (state.s.astype(F32),
                                            state.p.astype(F32)),
                                     (q_c, k_c, va_c, ld_c))
    # (T,B,Hkv,G,C,Dv+1) -> (B,H,Np,Dv+1)
    f_all = jnp.moveaxis(f_all, 0, 3).reshape(bsz, h, n_pad, dv + 1)
    f_all = f_all[:, :, :n]
    g = f_all[..., dv]
    o = safe_div(f_all[..., :dv], g[..., None]).astype(out_dtype)
    return o, g, GLAState(s_f, p_f)


# ---------------------------------------------------------------------------
# Backward (causal) — Eqs. 19-21 discipline, decay-gated
# ---------------------------------------------------------------------------

def gla_bwd_chunked(q, k, v, log_decay, o, g, omega, a: float, b: float,
                    chunk: int = 512):
    """Analytic gradient from residuals {q, k, v, ld, o, g} and omega.

    Returns (dq, dk, dv, dlog_decay) in the respective input dtypes.
    """
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    a32, b32 = jnp.asarray(a, F32), jnp.asarray(b, F32)

    # om_hat = omega / g and h_i = o_i . om_hat_i (paper Eq. 20); the
    # gated chain needs gmat = [om_hat, -h] = dF (normalizer column
    # carries the -h term)
    om_hat = safe_div(omega.astype(F32), g[..., None])
    h_vec = jnp.sum(o.astype(F32) * om_hat, axis=-1)  # (B,H,N)

    om_g = _group(_pad_to(om_hat, n_pad, 2), hkv)
    h_g = _group(_pad_to(h_vec[..., None], n_pad, 2), hkv)
    qg = _group(_pad_to(q, n_pad, 2), hkv)
    kp = _pad_to(k, n_pad, 2)
    vp = _pad_to(v, n_pad, 2)
    ldp = _pad_to(log_decay.astype(F32), n_pad, 2)
    ones = jnp.ones(vp.shape[:-1] + (1,), F32)
    vaug = jnp.concatenate([vp.astype(F32), ones], -1)       # [v, 1]
    qaug = jnp.concatenate([qg.astype(F32),
                            jnp.ones(qg.shape[:-1] + (1,), F32)], -1)

    q_c = _chunks(qg, c, 3)
    qa_c = _chunks(qaug, c, 3)
    k_c = _chunks(kp, c, 2)
    va_c = _chunks(vaug, c, 2)
    omh_c = _chunks(om_g, c, 3)
    h_c = _chunks(h_g, c, 3)
    ld_c = _chunks(ldp, c, 2)

    tril = jnp.tril(jnp.ones((c, c), bool))

    # ---- grad Q: forward scan carrying the forward's decayed state S
    def step_q(carry, inp):
        s = carry
        qc, kc, vac, omc, hc, ld = inp
        cl = jnp.cumsum(ld, axis=-1)
        total = cl[..., -1:]
        gmat = jnp.concatenate([omc, -hc], axis=-1)  # [om_hat, -h]
        sc = jnp.einsum("bhgie,bhje->bhgij", gmat, vac,
                        preferred_element_type=F32)
        sc = sc * _decay_mask(cl, tril)[:, :, None]
        dq_intra = jnp.einsum("bhgij,bhjd->bhgid", sc, kc,
                              preferred_element_type=F32)
        dq_inter = jnp.exp(cl)[:, :, None, :, None] * jnp.einsum(
            "bhgie,bhde->bhgid", gmat, s, preferred_element_type=F32)
        vw = jnp.exp(total - cl)[..., None] * vac
        s = (jnp.exp(total)[..., None] * s
             + jnp.einsum("bhjd,bhje->bhde", kc, vw,
                          preferred_element_type=F32))
        return s, b32 * (dq_intra + dq_inter)

    s0 = jnp.zeros((bsz, hkv, dk, dv + 1), F32)
    _, dq_all = jax.lax.scan(step_q, s0,
                             (q_c, k_c, va_c, omh_c, h_c, ld_c))

    # ---- grad K / grad V' fused: reverse scan, carry
    # U = suffix sum of decayed qaug (x) gmat
    def step_kv(carry, inp):
        u = carry  # (B,Hkv,Dk+1,Dv+1)
        qc, qac, kc, vac, omc, hc, ld = inp
        cl = jnp.cumsum(ld, axis=-1)
        total = cl[..., -1:]
        e_p = jnp.exp(total - cl)                          # token -> end
        gmat = jnp.concatenate([omc, -hc], axis=-1)
        # m_hi[p, i] = exp(cl_i - cl_p) for i >= p (clamped, see
        # _decay_mask)
        diff = jnp.minimum(cl[..., None, :] - cl[..., :, None], 0.0)
        m_hi = jnp.where(tril.T, jnp.exp(diff), 0.0)
        # dK intra: sum_{i>=p} M_ip (gmat_i . V'_p) q_i
        sc = jnp.einsum("bhgie,bhpe->bhgpi", gmat, vac,
                        preferred_element_type=F32) * m_hi[:, :, None]
        dk_intra = jnp.einsum("bhgpi,bhgid->bhpd", sc, qc,
                              preferred_element_type=F32)
        dk_inter = e_p[..., None] * jnp.einsum(
            "bhpe,bhde->bhpd", vac, u[..., :dk, :],
            preferred_element_type=F32)
        # dV' intra: sum_{i>=p} M_ip (a + b q_i.k_p) gmat_i
        att = a32 + b32 * jnp.einsum("bhgid,bhpd->bhgpi", qc, kc,
                                     preferred_element_type=F32)
        att = att * m_hi[:, :, None]
        dva_intra = jnp.einsum("bhgpi,bhgie->bhpe", att, gmat,
                               preferred_element_type=F32)
        dva_inter = e_p[..., None] * (
            b32 * jnp.einsum("bhpd,bhde->bhpe", kc, u[..., :dk, :],
                             preferred_element_type=F32)
            + a32 * u[..., dk, :][:, :, None, :])
        omw = jnp.exp(cl)[:, :, None, :, None] * gmat
        u = (jnp.exp(total)[..., None] * u
             + jnp.einsum("bhgic,bhgie->bhce", qac, omw,
                          preferred_element_type=F32))
        return u, (b32 * (dk_intra + dk_inter), dva_intra + dva_inter)

    u0 = jnp.zeros((bsz, hkv, dk + 1, dv + 1), F32)
    _, (dk_all, dva_all) = jax.lax.scan(
        step_kv, u0, (q_c, qa_c, k_c, va_c, omh_c, h_c, ld_c),
        reverse=True)

    dq = jnp.moveaxis(dq_all, 0, 3).reshape(bsz, h, n_pad, dk)[:, :, :n]
    dk_o = jnp.moveaxis(dk_all, 0, 2).reshape(bsz, hkv, n_pad, dk)[:, :, :n]
    dva = jnp.moveaxis(dva_all, 0, 2).reshape(bsz, hkv, n_pad,
                                              dv + 1)[:, :, :n]
    dv_o = dva[..., :dv]

    # dcl_p = -V'_p . dV'_p (row term df_i.f_i vanishes exactly under
    # the normalization); dld = reverse cumsum over tokens
    vaug_n = vaug[:, :, :n]
    dcl = -jnp.sum(vaug_n * dva, axis=-1)                    # (B,Hkv,N)
    dld = jnp.cumsum(dcl[..., ::-1], axis=-1)[..., ::-1]
    return (dq.astype(q.dtype), dk_o.astype(k.dtype),
            dv_o.astype(v.dtype), dld.astype(log_decay.dtype))


# ---------------------------------------------------------------------------
# Decode (serving): O(D^2) per token, state independent of context length
# ---------------------------------------------------------------------------

def gla_decode_step(state: GLAState, q, k, v, log_decay, a: float,
                    b: float):
    """One-token decode.  q: (B, H, Dk); k, v: (B, Hkv, D); log_decay:
    (B, Hkv).  Returns (new_state, o) with o: (B, H, Dv)."""
    bsz, h, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    kf, vf = k.astype(F32), v.astype(F32)
    gamma = jnp.exp(log_decay.astype(F32))                   # (B,Hkv)
    vaug = jnp.concatenate([vf, jnp.ones((bsz, hkv, 1), F32)], -1)
    s = (gamma[..., None, None] * state.s.astype(F32)
         + kf[..., :, None] * vaug[..., None, :])
    p = gamma[..., None] * state.p.astype(F32) + vaug
    qg = q.reshape(bsz, hkv, h // hkv, dk)
    f = (a * p[:, :, None, :]
         + b * jnp.einsum("bhgd,bhde->bhge", qg.astype(F32), s,
                          preferred_element_type=F32))
    o = safe_div(f[..., :dv], f[..., dv:])
    return GLAState(s, p), o.reshape(bsz, h, dv).astype(q.dtype)
