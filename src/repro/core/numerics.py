"""Numerical helpers shared by the linear-attention core (paper §3.3)."""
from __future__ import annotations

import jax.numpy as jnp


def l2_normalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-6) -> jnp.ndarray:
    """Row-wise l2 normalization, paper Eq. 22: q_i <- q_i / ||q_i||.

    Computed in f32 and cast back so bf16 inputs do not lose the scale.
    """
    xf = x.astype(jnp.float32)
    inv = jnp.reciprocal(jnp.sqrt(jnp.sum(xf * xf, axis=axis, keepdims=True) + eps))
    return (xf * inv).astype(x.dtype)


def safe_div(num: jnp.ndarray, den: jnp.ndarray, eps: float = 1e-30) -> jnp.ndarray:
    """num / den with exact zeros in `den` (padding rows) mapped to 0.

    With the paper's normalization (Eq. 22) and a,b > 0 the denominator
    g_i = sum_{n<=i} (a + b q_i.k_n) >= i(a - b) is non-negative; zeros only
    appear for padded rows which callers slice away.
    """
    den_safe = jnp.where(jnp.abs(den) < eps, 1.0, den)
    return jnp.where(jnp.abs(den) < eps, 0.0, num / den_safe)
