"""Chunked state-space duality (SSD / Mamba-2) — scalar-decay linear attention.

The paper (Appendix B, Table 3) identifies Mamba-2's recurrence
    S_t = gamma_t S_{t-1} + k_t v_t^T,   o_t = q_t S_t
as gated linear attention.  This module generalizes the chunked LA scan
of `core.chunked` with a per-token per-head scalar decay.

GROUPED q/k (beyond-paper perf): Mamba-2 shares B (keys) and C (queries)
across all heads of a group — materializing them per head costs an
H-fold blowup in both flops (the Q K^T product) and bytes.  Every
function here takes q, k of shape (B, G, N, Dk) with G | H; the Q K^T
product is computed ONCE per group and only the per-head decay masks and
value contractions run at H (mirroring the paper's GQA handling in
core/chunked.py).  G == H recovers the ungrouped form.

All decay algebra is done in log space for stability (within-chunk
exponents are differences of monotone cumsums, always <= 0).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class SSDState(NamedTuple):
    s: jnp.ndarray  # (B, H, Dk, Dv)


def init_ssd_state(batch: int, heads: int, dk: int, dv: int,
                   dtype=jnp.float32) -> SSDState:
    return SSDState(s=jnp.zeros((batch, heads, dk, dv), dtype))


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


def _group(x, g: int):
    """(B, H, ...) -> (B, G, H/G, ...)."""
    b, h = x.shape[:2]
    return x.reshape(b, g, h // g, *x.shape[2:])


def _chop(x, t, c):
    """(B, ..., N, ...) with N at axis -2 for 4/5-D tensors."""
    axis = x.ndim - 2
    new = x.shape[:axis] + (t, c) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new), axis, 0)


def _chop_l(x, t, c):
    """last-axis chop for (B, G, Hg, N) decay tensors."""
    new = x.shape[:-1] + (t, c)
    return jnp.moveaxis(x.reshape(new), -2, 0)


def ssd_fwd_chunked(q, k, v, log_decay, chunk: int = 128,
                    state: SSDState | None = None):
    """q, k: (B, G, N, Dk) shared per group (G | H); v: (B, H, N, Dv);
    log_decay: (B, H, N) <= 0.  Returns (o, final_state (B, H, Dk, Dv))."""
    bsz, g, n, dk = q.shape
    h = v.shape[1]
    hg = h // g
    dv = v.shape[-1]
    out_dtype = v.dtype
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    # inputs stay in their dtype (bf16 in production) — casting whole
    # arrays to f32 makes XLA hoist the convert through pads/slices and
    # run the surrounding layers in f32 (observed 2x traffic); chunk
    # accumulation still happens in f32 via preferred_element_type and
    # the f32 decay weights.
    qp = _pad_to(q, n_pad, 2)
    kp = _pad_to(k, n_pad, 2)
    vp = _group(_pad_to(v, n_pad, 2), g)
    ldp = _group(_pad_to(log_decay.astype(F32), n_pad, 2), g)

    q_c, k_c = _chop(qp, t, c), _chop(kp, t, c)     # (T, B, G, C, Dk)
    v_c = _chop(vp, t, c)                           # (T, B, G, Hg, C, Dv)
    ld_c = _chop_l(ldp, t, c)                       # (T, B, G, Hg, C)

    if state is None:
        state = init_ssd_state(bsz, h, dk, dv)
    s0 = _group(state.s.astype(F32), g)             # f32 carried state
    mask = jnp.tril(jnp.ones((c, c), bool))

    def step(s, inp):
        qc, kc, vc, ld = inp
        cl = jnp.cumsum(ld, axis=-1)                # (B, G, Hg, C)
        total = cl[..., -1:]
        # Q K^T once per group; decay mask per head
        att = jnp.einsum("bgid,bgjd->bgij", qc, kc,
                         preferred_element_type=F32)
        diff = cl[..., :, None] - cl[..., None, :]
        w = att[:, :, None] * jnp.where(mask, jnp.exp(diff), 0.0)
        o_intra = jnp.einsum("bghij,bghje->bghie", w, vc,
                             preferred_element_type=F32)
        o_inter = jnp.exp(cl)[..., None] * jnp.einsum(
            "bgid,bghde->bghie", qc, s, preferred_element_type=F32)
        # state: weight v (per head) instead of broadcasting k
        vw = jnp.exp(total - cl)[..., None] * vc
        s = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bgjd,bghje->bghde", kc, vw, preferred_element_type=F32)
        return s, o_intra + o_inter

    s_f, o_all = jax.lax.scan(step, s0, (q_c, k_c, v_c, ld_c))
    # (T, B, G, Hg, C, Dv) -> (B, G, Hg, T, C, Dv) -> (B, H, N, Dv)
    o = jnp.moveaxis(o_all, 0, 3).reshape(bsz, h, n_pad, dv)[:, :, :n]
    return o.astype(out_dtype), SSDState(s_f.reshape(bsz, h, dk, dv))


def ssd_decode_step(state: SSDState, q, k, v, log_decay):
    """One-token decode.  q, k: (B, G, Dk); v: (B, H, Dv); ld: (B, H)."""
    bsz, g, dk = q.shape
    h = v.shape[1]
    gamma = jnp.exp(log_decay.astype(F32))[..., None, None]
    kf = _group(jnp.repeat(k, h // g, axis=1) if g != h else k, 1)[:, 0]
    s = gamma * state.s + kf.astype(F32)[..., :, None] \
        * v.astype(F32)[..., None, :]
    qf = jnp.repeat(q, h // g, axis=1) if g != h else q
    o = jnp.einsum("bhd,bhde->bhe", qf.astype(F32), s)
    return SSDState(s), o.astype(v.dtype)


# ---------------------------------------------------------------------------
# Analytic backward — the paper's Eqs. 19-21 discipline EXTENDED to the
# decay-gated mixer (beyond-paper: the paper derives it only for the
# undecayed normalized kernel).  With M_in = exp(cl_i - cl_n) and
# S_i = sum_{n<=i} M_in k_n v_n^T (the forward state):
#
#   dq_i  = sum_{h in group} S^h_i @ Omega_{h,i}   (forward chunk scan)
#   dk_n  = sum_{h} U^h_n @ v_{h,n},  dv_n = U_n^T @ k_n   (reverse scan,
#            U^h_n = sum_{i>=n} M^h_in q_i Omega_{h,i}^T)
#   dcl_j = Omega_j . o_j - v_j . dv_j             (log-decay chain)
#   dld_t = sum_{j>=t} dcl_j                       (reverse cumsum)
#
# Residuals are {q, k, v, log_decay, o}: O(N D) — autodiff through the
# chunk scan would store the O(N C) masked decay/attention blocks.
# ---------------------------------------------------------------------------

def ssd_bwd_chunked(q, k, v, log_decay, o, omega, chunk: int = 128):
    """Returns (dq, dk, dv, dlog_decay); dq/dk are group-summed."""
    bsz, g, n, dk = q.shape
    h = v.shape[1]
    dv = v.shape[-1]
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    qp = _pad_to(q, n_pad, 2)
    kp = _pad_to(k, n_pad, 2)
    vp = _group(_pad_to(v, n_pad, 2), g)
    omp = _group(_pad_to(omega, n_pad, 2), g)
    ldp = _group(_pad_to(log_decay.astype(F32), n_pad, 2), g)

    q_c, k_c = _chop(qp, t, c), _chop(kp, t, c)
    v_c, om_c = _chop(vp, t, c), _chop(omp, t, c)
    ld_c = _chop_l(ldp, t, c)
    mask_lo = jnp.tril(jnp.ones((c, c), bool))

    # ---- dq: forward scan carrying the same state S as the forward pass
    def step_q(s, inp):
        qc, kc, vc, omc, ld = inp
        cl = jnp.cumsum(ld, axis=-1)
        total = cl[..., -1:]
        p = jnp.einsum("bghie,bghne->bghin", omc, vc,
                       preferred_element_type=F32)
        diff = cl[..., :, None] - cl[..., None, :]
        w = p * jnp.where(mask_lo, jnp.exp(diff), 0.0)
        dq_intra = jnp.einsum("bghin,bgnd->bgid", w, kc,
                              preferred_element_type=F32)
        omw = jnp.exp(cl)[..., None] * omc
        dq_inter = jnp.einsum("bghde,bghie->bgid", s, omw,
                              preferred_element_type=F32)
        vw = jnp.exp(total - cl)[..., None] * vc
        s = jnp.exp(total)[..., None] * s + jnp.einsum(
            "bgjd,bghje->bghde", kc, vw, preferred_element_type=F32)
        return s, dq_intra + dq_inter

    s0 = jnp.zeros((bsz, g, h // g, dk, dv), F32)
    _, dq_all = jax.lax.scan(step_q, s0, (q_c, k_c, v_c, om_c, ld_c))

    # ---- dk, dv: reverse scan carrying U = sum_{later} decayed q Om^T
    def step_kv(u, inp):
        qc, kc, vc, omc, ld = inp
        cl = jnp.cumsum(ld, axis=-1)
        total = cl[..., -1:]
        e_n = jnp.exp(total - cl)                        # decay n -> end
        diff = cl[..., :, None] - cl[..., None, :]
        m_hi = jnp.where(mask_lo.T, jnp.exp(diff.swapaxes(-1, -2)), 0.0)
        # m_hi[n, i] = exp(cl_i - cl_n) for i >= n
        p = jnp.einsum("bghie,bghne->bghni", omc, vc,
                       preferred_element_type=F32)       # p[n,i]=Om_i.v_n
        dk_intra = jnp.einsum("bghni,bgid->bgnd", p * m_hi, qc,
                              preferred_element_type=F32)
        s_qk = jnp.einsum("bgid,bgnd->bgni", qc, kc,
                          preferred_element_type=F32)    # s[n,i]=q_i.k_n
        w2 = s_qk[:, :, None] * m_hi
        dv_intra = jnp.einsum("bghni,bghie->bghne", w2, omc,
                              preferred_element_type=F32)
        vw = e_n[..., None] * vc
        dk_inter = jnp.einsum("bghde,bghne->bgnd", u, vw,
                              preferred_element_type=F32)
        dv_inter = e_n[..., None] * jnp.einsum(
            "bghde,bgnd->bghne", u, kc, preferred_element_type=F32)
        omw = jnp.exp(cl)[..., None] * omc
        u = jnp.exp(total)[..., None] * u + jnp.einsum(
            "bgid,bghie->bghde", qc, omw, preferred_element_type=F32)
        return u, (dk_intra + dk_inter, dv_intra + dv_inter)

    u0 = jnp.zeros((bsz, g, h // g, dk, dv), F32)
    _, (dk_all, dv_all) = jax.lax.scan(
        step_kv, u0, (q_c, k_c, v_c, om_c, ld_c), reverse=True)

    dq_o = jnp.moveaxis(dq_all, 0, 2).reshape(bsz, g, n_pad, dk)[:, :, :n]
    dk_o = jnp.moveaxis(dk_all, 0, 2).reshape(bsz, g, n_pad, dk)[:, :, :n]
    dv_o = jnp.moveaxis(dv_all, 0, 3).reshape(bsz, h, n_pad, dv)[:, :, :n]

    # ---- dlog_decay: dcl_j = Om_j.o_j - v_j.dv_j; dld = reverse cumsum
    dcl = (jnp.sum(omega.astype(F32) * o.astype(F32), -1)
           - jnp.sum(v.astype(F32) * dv_o, -1))           # (B, H, N)
    dld = jnp.cumsum(dcl[..., ::-1], axis=-1)[..., ::-1]
    return (dq_o.astype(q.dtype), dk_o.astype(k.dtype),
            dv_o.astype(v.dtype), dld.astype(log_decay.dtype))


def ssd_causal(q, k, v, log_decay, chunk: int = 128,
               backend: str = "auto"):
    """SSD with the analytic O(N D) backward (training entry point).

    Thin alias of `kernels.ops.ssd_causal`: impl selection goes through
    the "ssd"-family KernelImpl registry (xla / pallas / pallas_interpret
    / ref), not an internal TPU branch.  Kept here for callers that think
    in core-scan terms; the custom-vjp wiring lives in kernels/ops.py.
    """
    from repro.kernels.ops import ssd_causal as _entry  # lazy: no cycle
    return _entry(q, k, v, log_decay, chunk, backend)
