"""Fused single-kernel decode steps (ROADMAP "fused epilogues").

Decode is one token per slot, so its cost is pure HBM streaming — yet
the unfused paths materialize intermediates between the attention math
and its epilogue:

  * linear/GLA decode (core.chunked.la_decode_step /
    core.gla.gla_decode_step) writes the un-normalized f = a*p + b*q.S
    to HBM, then runs the normalizer divide (and GLA's decay gate) as
    separate XLA ops — four round trips over O(B*Hkv*D^2) state;
  * softmax decode finalizes the online-softmax divide outside the
    kernel, and the contiguous-cache path never had a kernel at all
    (softmax_decode is an einsum chain with a (B,H,S) score tensor);
  * paged decode runs one grid cell per QUERY head, streaming each KV
    page `group` times under GQA.

This module is the fused alternative, one Pallas kernel per decode
step per family:

  `la_decode_fused_pallas` / `gla_decode_fused_pallas` — grid (B, Hkv);
  each cell reads the slot's recurrent state page (S: (Dk, Dv+1), p:
  (Dv+1)), applies the decay gate (GLA), rank-1-updates the state IN
  PLACE (input_output_aliases donates the state buffers), computes the
  grouped q.S and normalizer dots, and writes the already-divided
  output — one HBM round trip over the state instead of four.

  `softmax_decode_fused_pallas` — contiguous-cache softmax decode as an
  online-softmax kernel: grid (B, Hkv, S/block_k), grouped query heads
  (GQA head-fold: the (G, D) query block rides in one grid cell, each
  KV block streams ONCE per kv head), running max/sum in VMEM scratch,
  and the finalize divide folded into the last grid step — no (B, H, D)
  accumulator ever leaves VMEM.

  `paged_decode_fused_pallas` — the paged-KV walk of
  kernels/paged_attention.py with the same GQA head-fold: grid
  (B, Hkv, Pmax/ppb) instead of (B, H, Pmax), so arena pages are
  DMA'd once per kv head, not once per query head.

Shared conventions with the unfused kernels: f32 accumulation,
`preferred_element_type` on every dot, per-slot lengths via scalar
prefetch with the page/block walk clamped at the slot's frontier, and
a guarded finalize so a length-0 (retired) slot yields zeros, never
NaN.  The linear/GLA normalizer divide replicates
core.numerics.safe_div semantics (exact-zero denominators map to 0).

Dispatch lives in kernels/ops.py as the `*_decode_fused` KernelImpl
families; the xla/ref impls there ARE the unfused compositions, so the
fallback is byte-identical by construction.  Parity is pinned in
tests/test_decode_fused.py; docs/fused_decode.md has the HBM-traffic
accounting.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.defaults import DEFAULT_TILES

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

F32 = jnp.float32
NEG_INF = -1e30
_SAFE_EPS = 1e-30  # core.numerics.safe_div's zero-denominator threshold
_BK = DEFAULT_TILES["softmax_decode_fused"]["block_k"]
_PPB = DEFAULT_TILES["paged_decode_fused"]["pages_per_block"]


# ---------------------------------------------------------------------------
# Linear / GLA: state-update + normalizer epilogue in one kernel
# ---------------------------------------------------------------------------

def _recurrent_step_kernel(*refs, a: float, b: float, dv: int,
                           gated: bool):
    if gated:
        s_ref, p_ref, q_ref, k_ref, v_ref, ld_ref = refs[:6]
        s_out, p_out, o_ref = refs[6:]
    else:
        s_ref, p_ref, q_ref, k_ref, v_ref = refs[:5]
        s_out, p_out, o_ref = refs[5:]
    s = s_ref[0, 0].astype(F32)                    # (dk, dv+1)
    p = p_ref[0].astype(F32)                       # (1, dv+1)
    k = k_ref[0].astype(F32)                       # (1, dk)
    v = v_ref[0].astype(F32)                       # (1, dv)
    vaug = jnp.concatenate([v, jnp.ones((1, 1), F32)], -1)   # (1, dv+1)
    if gated:
        gamma = jnp.exp(ld_ref[...].astype(F32))   # (1, 1)
        s = gamma * s
        p = gamma * p
    s_new = s + jnp.dot(k.T, vaug, preferred_element_type=F32)
    p_new = p + vaug
    qg = q_ref[0, 0].astype(F32)                   # (g, dk)
    f = a * p_new + b * jnp.dot(qg, s_new, preferred_element_type=F32)
    num, den = f[:, :dv], f[:, dv:]                # (g, dv), (g, 1)
    # safe_div inline: exact-zero denominators (padding rows) -> 0
    den_safe = jnp.where(jnp.abs(den) < _SAFE_EPS, 1.0, den)
    o = jnp.where(jnp.abs(den) < _SAFE_EPS, 0.0, num / den_safe)
    s_out[0, 0] = s_new.astype(s_out.dtype)
    p_out[0] = p_new.astype(p_out.dtype)
    o_ref[0, 0] = o.astype(o_ref.dtype)


def _recurrent_decode_call(s, p, q, k, v, log_decay, a, b, interpret):
    bsz, h, dk = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    dv1 = s.shape[-1]
    assert dv1 == dv + 1, (s.shape, v.shape)
    qg = q.reshape(bsz, hkv, g, dk)
    gated = log_decay is not None

    in_specs = [
        pl.BlockSpec((1, 1, dk, dv1), lambda bi, hi: (bi, hi, 0, 0)),
        pl.BlockSpec((1, 1, dv1), lambda bi, hi: (bi, hi, 0)),
        pl.BlockSpec((1, 1, g, dk), lambda bi, hi: (bi, hi, 0, 0)),
        pl.BlockSpec((1, 1, dk), lambda bi, hi: (bi, hi, 0)),
        pl.BlockSpec((1, 1, dv), lambda bi, hi: (bi, hi, 0)),
    ]
    args = [s, p, qg, k, v]
    if gated:
        in_specs.append(pl.BlockSpec((1, 1), lambda bi, hi: (bi, hi)))
        args.append(log_decay)

    s_new, p_new, og = pl.pallas_call(
        functools.partial(_recurrent_step_kernel, a=a, b=b, dv=dv,
                          gated=gated),
        grid=(bsz, hkv),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, dk, dv1), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, dv1), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, g, dv), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(s.shape, s.dtype),
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct((bsz, hkv, g, dv), q.dtype),
        ],
        # the state is read, rank-1-updated, and rewritten in one pass;
        # donating it makes the update truly in place (no arena copy)
        input_output_aliases={0: 0, 1: 1},
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(*args)
    return s_new, p_new, og.reshape(bsz, h, dv)


def la_decode_fused_pallas(s, p, q, k, v, a: float, b: float,
                           interpret: bool = False):
    """One fused linear-attention decode step.

    s: (B, Hkv, Dk, Dv+1) f32 state; p: (B, Hkv, Dv+1) f32 normalizer;
    q: (B, H, Dk); k, v: (B, Hkv, D).  Returns (s_new, p_new, o) with
    o: (B, H, Dv) in q.dtype — already divided, nothing left to do.
    """
    return _recurrent_decode_call(s.astype(F32), p.astype(F32),
                                  q, k, v, None, a, b, interpret)


def gla_decode_fused_pallas(s, p, q, k, v, log_decay, a: float, b: float,
                            interpret: bool = False):
    """One fused decay-gated (GLA) decode step.

    Same contract as `la_decode_fused_pallas` plus log_decay: (B, Hkv)
    per-step log gate; the kernel applies gamma = exp(log_decay) to the
    state before the rank-1 update.
    """
    return _recurrent_decode_call(s.astype(F32), p.astype(F32),
                                  q, k, v, log_decay, a, b, interpret)


# ---------------------------------------------------------------------------
# Softmax (contiguous cache): online softmax + finalize + GQA head-fold
# ---------------------------------------------------------------------------

def _softmax_fused_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                          acc_ref, m_ref, l_ref, *, scale: float,
                          nblk: int, bk: int):
    bi = pl.program_id(0)
    blk = pl.program_id(2)
    length = len_ref[bi]

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # blocks at or past the slot's frontier were clamped in the index
    # map (no DMA) and contribute nothing — skip their compute
    @pl.when(blk * bk < length)
    def _step():
        q = q_ref[0, 0].astype(F32)                # (g, d)
        k = k_ref[0, 0].astype(F32)                # (bk, d)
        v = v_ref[0, 0].astype(F32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=F32)  # (g, bk)
        jj = blk * bk + lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(jj < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(blk == nblk - 1)
    def _finalize():
        # a length-0 (retired) slot accumulates l == 0; guard the
        # divide so it finalizes to zeros, not NaN
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def softmax_decode_fused_pallas(q, k, v, lengths, block_k: int = _BK,
                                interpret: bool = False):
    """Fused contiguous-cache softmax decode.

    q: (B, H, 1, d); k, v: (B, Hkv, S, d); lengths: (B,) int32 valid
    keys per slot.  Grid (B, Hkv, ceil(S/block_k)): grouped query heads
    share one grid cell (each KV block streams once per KV head, not
    once per query head) and the finalize divide runs inside the last
    grid step.  A length-0 slot yields zeros (paged-family semantics).
    """
    b, h, nq, d = q.shape
    assert nq == 1, f"softmax_decode_fused is a decode kernel (nq={nq})"
    hkv, s_len = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    bk = max(1, min(block_k, s_len))
    pad = (-s_len) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nblk = (s_len + pad) // bk
    scale = 1.0 / d ** 0.5
    qg = q.reshape(b, hkv, g, d)

    def kv_index(bi, hi, blk, lens):
        # clamp the walk at the slot's last populated block: iterations
        # past it keep the same block index, so no new DMA is issued
        frontier = jnp.maximum(lens[bi] - 1, 0) // bk
        return (bi, hi, jnp.minimum(blk, frontier), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, blk, lens: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_index),
            pl.BlockSpec((1, 1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda bi, hi, blk, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), F32),
            pltpu.VMEM((g, 1), F32),
            pltpu.VMEM((g, 1), F32),
        ],
    )
    o = pl.pallas_call(
        functools.partial(_softmax_fused_kernel, scale=scale, nblk=nblk,
                          bk=bk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k, v)
    return o.reshape(b, h, 1, d)


# ---------------------------------------------------------------------------
# Paged KV: the page walk of kernels/paged_attention.py, head-folded
# ---------------------------------------------------------------------------

def _paged_fused_kernel(pt_ref, len_ref, q_ref, *refs, scale: float,
                        nblk: int, ppb: int):
    kv_refs, o_ref = refs[:2 * ppb], refs[2 * ppb]
    acc_ref, m_ref, l_ref = refs[2 * ppb + 1:]
    bi = pl.program_id(0)
    blk = pl.program_id(2)
    length = len_ref[bi]
    ps = kv_refs[0].shape[2]

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    for j in range(ppb):
        pi = blk * ppb + j
        k_ref, v_ref = kv_refs[2 * j], kv_refs[2 * j + 1]

        @pl.when(pi * ps < length)
        def _step(k_ref=k_ref, v_ref=v_ref, pi=pi):
            q = q_ref[0, 0].astype(F32)            # (g, d)
            k = k_ref[0, 0].astype(F32)            # (ps, d)
            v = v_ref[0, 0].astype(F32)
            s = scale * jnp.dot(q, k.T,
                                preferred_element_type=F32)  # (g, ps)
            jj = pi * ps + lax.broadcasted_iota(jnp.int32, (1, ps), 1)
            s = jnp.where(jj < length, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
            acc_ref[...] = corr * acc_ref[...] + jnp.dot(
                p, v, preferred_element_type=F32)
            m_ref[...] = m_new

    @pl.when(blk == nblk - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def paged_decode_fused_pallas(q, k_pages, v_pages, page_table, lengths,
                              pages_per_block: int = _PPB,
                              interpret: bool = False):
    """Fused paged-KV decode; same contract as paged_attention_pallas.

    The grid is (B, Hkv, Pmax/ppb) — the GQA head-fold: each arena page
    is DMA'd once per KV head and scored against all `group` query
    heads in that cell, vs once per QUERY head in the unfused kernel.
    The finalize divide stays in the epilogue as before.
    """
    b, h, nq, d = q.shape
    assert nq == 1, f"paged_decode_fused is a decode kernel (nq={nq})"
    hkv, ps = k_pages.shape[1], k_pages.shape[2]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    pmax = page_table.shape[1]
    ppb = max(1, min(pages_per_block, pmax))
    nblk = -(-pmax // ppb)
    scale = 1.0 / d ** 0.5
    qg = q.reshape(b, hkv, g, d)

    def kv_index_for(j):
        def kv_index(bi, hi, blk, pt, lens):
            frontier = jnp.maximum(lens[bi] - 1, 0) // ps
            pi = jnp.minimum(blk * ppb + j, frontier)
            return (pt[bi, pi], hi, 0, 0)
        return kv_index

    kv_specs = []
    for j in range(ppb):
        kv_specs += [pl.BlockSpec((1, 1, ps, d), kv_index_for(j)),
                     pl.BlockSpec((1, 1, ps, d), kv_index_for(j))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bi, hi, blk, pt, lens: (bi, hi, 0, 0)),
            *kv_specs,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda bi, hi, blk, pt, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), F32),
            pltpu.VMEM((g, 1), F32),
            pltpu.VMEM((g, 1), F32),
        ],
    )
    kv_args = []
    for _ in range(ppb):
        kv_args += [k_pages, v_pages]
    o = pl.pallas_call(
        functools.partial(_paged_fused_kernel, scale=scale, nblk=nblk,
                          ppb=ppb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, *kv_args)
    return o.reshape(b, h, 1, d)
