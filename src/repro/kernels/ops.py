"""Public jit'd entry points for the paper's linear attention.

Backend dispatch:
  "xla"              chunked lax.scan (core.chunked) — CPU / dry-run / any backend
  "pallas"           Pallas TPU kernels (kernels.linear_attention)
  "pallas_interpret" Pallas kernels in interpret mode (CPU validation)
  "ref"              quadratic oracle (tests only)
  "auto"             "pallas" on TPU, else "xla"

The causal path is wrapped in jax.custom_vjp implementing the paper's
analytic backward (Eqs. 19-21): residuals are {q, k, v, o, g} — O(N D)
memory — instead of the O(N D^2) intermediates autodiff would store.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import chunked as _chunked
from repro.core.chunked import LAState, init_state, la_decode_step, la_noncausal
from repro.kernels import ref as _ref

__all__ = [
    "la_causal", "la_prefill", "la_noncausal", "la_decode_step",
    "LAState", "init_state", "default_backend",
]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


def _fwd_dispatch(q, k, v, a, b, chunk, backend):
    backend = _resolve(backend)
    if backend == "xla":
        o, g, _ = _chunked.la_fwd_chunked(q, k, v, a, b, chunk)
        return o, g
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import linear_attention as _pl
        return _pl.la_fwd_pallas(q, k, v, a, b, chunk,
                                 interpret=backend == "pallas_interpret")
    if backend == "ref":
        o = _ref.la_ref(q, k, v, a, b, causal=True)
        # oracle recomputes g for residuals
        kk = _ref._expand_kv(k, q.shape[1]).astype(jnp.float32)
        s = jnp.einsum("bhid,bhjd->bhij", q.astype(jnp.float32), kk)
        w = a + b * s
        n = q.shape[2]
        w = jnp.where(jnp.tril(jnp.ones((n, n), bool)), w, 0.0)
        return o, w.sum(-1)
    raise ValueError(f"unknown backend {backend!r}")


def _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend):
    backend = _resolve(backend)
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels import linear_attention as _pl
        return _pl.la_bwd_pallas(q, k, v, o, g, omega, a, b, chunk,
                                 interpret=backend == "pallas_interpret")
    return _chunked.la_bwd_chunked(q, k, v, o, g, omega, a, b, chunk)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def la_causal(q, k, v, a: float = 1.0, b: float = 1.0,
              chunk: int = 128, backend: str = "auto"):
    """Causal normalized linear attention (paper Eqs. 4-9).

    q: (B, H, N, D); k, v: (B, Hkv, N, D), Hkv | H.  Returns (B, H, N, D).
    """
    o, _ = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o


def _la_causal_fwd(q, k, v, a, b, chunk, backend):
    o, g = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o, (q, k, v, o, g)


def _la_causal_bwd(a, b, chunk, backend, res, omega):
    q, k, v, o, g = res
    dq, dk, dv = _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend)
    return dq, dk, dv


la_causal.defvjp(_la_causal_fwd, _la_causal_bwd)


def la_prefill(q, k, v, a: float = 1.0, b: float = 1.0, chunk: int = 128,
               state: LAState | None = None):
    """Causal LA that also returns the recurrent state for decode.

    Inference-only (no custom grad needed).  Returns (o, LAState).
    """
    o, _, st = _chunked.la_fwd_chunked(q, k, v, a, b, chunk, state=state)
    return o, st


# ---------------------------------------------------------------------------
# Learnable kernel coefficients (paper §2.2: "the coefficients either as
# the Taylor expansion of the exponential or as learnable parameters").
#
# f and g are LINEAR in (a, b): f = a·F1 + b·F2, g = a·G1 + b·G2 with
# F1 = cumsum(v), G1_i = i, and F2/G2 recoverable from the residuals
# (F2 = (o·g − a·F1)/b).  Hence
#     ∂o/∂a = (F1 − o·G1)/g        (one O(N·D) cumsum)
#     ∂o/∂b = −(a/b)·∂o/∂a         (o depends only on a/b, so
#                                    a·da + b·db = 0 exactly)
# — learnable coefficients cost one cumsum + a reduction on top of the
# paper's analytic backward.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def la_causal_learnable(q, k, v, a, b, chunk: int = 512,
                        backend: str = "auto"):
    """Causal normalized LA with DIFFERENTIABLE scalar coefficients.

    a, b: scalar jnp arrays (learnable parameters).  Same output as
    la_causal; gradients flow to q, k, v, a and b.
    """
    o, _ = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o


def _la_learn_fwd(q, k, v, a, b, chunk, backend):
    o, g = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o, (q, k, v, o, g, a, b)


def _la_learn_bwd(chunk, backend, res, omega):
    q, k, v, o, g, a, b = res
    dq, dk, dv = _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend)
    f32 = jnp.float32
    kk = _ref._expand_kv(v, q.shape[1]) if v.shape[1] != q.shape[1] else v
    f1 = jnp.cumsum(kk.astype(f32), axis=2)              # (B, H, N, D)
    n = q.shape[2]
    g1 = jnp.arange(1, n + 1, dtype=f32)[None, None, :, None]
    do_da = (f1 - o.astype(f32) * g1) / g[..., None]
    da = jnp.sum(omega.astype(f32) * do_da)
    db = -(a.astype(f32) / b.astype(f32)) * da
    return dq, dk, dv, da.astype(a.dtype), db.astype(b.dtype)


la_causal_learnable.defvjp(_la_learn_fwd, _la_learn_bwd)
