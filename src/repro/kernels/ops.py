"""Public jit'd entry points for the paper's kernels + the KernelImpl registry.

Kernel selection is data-driven: each (family, impl) pair is a registered
`KernelImpl`.  Families are the attention score shapes ("linear" — the
paper's kernelized attention —, "softmax", the Regular-Attention
baseline, "softmax_decode", its one-token-per-slot contiguous-cache
decode, "paged", the paged-KV serving decode of docs/paged_kv.md,
"ssd", the decay-gated Mamba-2 duality of Appendix B, and "gla", the
decay-gated normalized LA of core/gla.py); impls are execution
backends:

  "xla"              chunked lax.scan (core.chunked / core.softmax)
  "pallas"           Pallas TPU kernels (kernels.linear_attention / .flash_attention)
  "pallas_interpret" the same Pallas kernels in interpret mode (CPU validation)
  "ref"              quadratic oracle (tests only)
  "auto"             resolves to "pallas" on TPU, else "xla"

Adding an impl is one `register_kernel(...)` call; `get_kernel` raises an
actionable error listing the registered impls for unknown names.

The causal linear path is wrapped in jax.custom_vjp implementing the
paper's analytic backward (Eqs. 19-21): residuals are {q, k, v, o, g} —
O(N D) memory — instead of the O(N D^2) intermediates autodiff would
store.  The causal softmax path gets the same treatment (flash v2):
residuals {q, k, v, o, lse} with a recomputation-based flash backward,
so the FlashAttention-2-style baseline trains through pallas exactly
like the paper's kernel does.  The custom-vjp wiring lives here, once,
regardless of impl.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import chunked as _chunked
from repro.core import gla as _gla
from repro.core import softmax as _softmax
from repro.core import ssd as _ssd
from repro.core.chunked import LAState, init_state, la_decode_step, la_noncausal
from repro.core.gla import GLAState, init_gla_state
from repro.kernels import ref as _ref
from repro.kernels.defaults import DEFAULT_SCAN_CHUNK, DEFAULT_TILES

__all__ = [
    "KernelImpl", "register_kernel", "get_kernel", "kernel_names",
    "la_causal", "la_causal_learnable", "la_prefill", "la_noncausal",
    "la_decode_step", "softmax_attention", "softmax_causal",
    "softmax_decode", "paged_attention", "ssd_causal", "gla_causal",
    "gla_prefill", "gla_decode_step", "LAState", "init_state",
    "GLAState", "init_gla_state", "default_backend", "DEFAULT_CHUNK",
    "set_tuning_cache", "get_tuning_cache", "tuned_tiles",
    "la_decode_step_fused", "gla_decode_step_fused",
    "softmax_decode_fused", "paged_attention_fused",
]

# one chunk default everywhere (configs.base.LACfg is the schema of
# record); the literal lives in kernels/defaults.py with the tile table
DEFAULT_CHUNK = DEFAULT_SCAN_CHUNK


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# Autotuned tile resolution (repro.tune)
#
# Every impl wrapper below consults the process-wide tuning cache (if
# one is installed) for its tile sizes — chunk for the chunked-scan
# families, block_q/block_k for flash, pages_per_block for paged decode
# — and falls back to the caller's value / kernels.defaults otherwise.
# The lookup happens at TRACE time (shapes are concrete), so a cache
# hit changes only the lowered kernel, never the math: each family's
# output is invariant in its tile sizes (pinned by tests).  With no
# cache installed (the default) dispatch is byte-identical to the
# untuned behavior.  `repro.tune.activate` installs a cache; tests may
# call `set_tuning_cache` directly.
# ---------------------------------------------------------------------------

_TUNING_CACHE = None  # duck-typed: anything with .lookup(...)


def set_tuning_cache(cache):
    """Install (or clear, with None) the tuning cache consulted by
    kernel dispatch.  Returns the previously installed cache."""
    global _TUNING_CACHE
    prev, _TUNING_CACHE = _TUNING_CACHE, cache
    return prev


def get_tuning_cache():
    return _TUNING_CACHE


def tuned_tiles(family: str, impl: str, op: str, shape: dict,
                dtype) -> dict:
    """Cache-resolved tile overrides for one kernel launch ({} = miss)."""
    if _TUNING_CACHE is None:
        return {}
    return _TUNING_CACHE.lookup(family, impl, op, shape, dtype) or {}


def _attn_shape(q, k) -> dict:
    """Shape-bucket inputs for the (B, H/Hkv, N, D) attention layouts."""
    return {"b": q.shape[0], "h": q.shape[1], "hkv": k.shape[1],
            "n": q.shape[2], "d": q.shape[3]}


def _tile(family, impl, op, shape, dtype, param, fallback):
    return tuned_tiles(family, impl, op, shape, dtype).get(param, fallback)


# ---------------------------------------------------------------------------
# KernelImpl registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One execution backend of one attention family.

    fwd: linear family:  (q, k, v, a, b, chunk) -> (o, g)
         softmax family: (q, k, v, causal, chunk, q_offset) -> o
         ssd family:     (q, k, v, log_decay, chunk) -> o
    bwd: linear family: (q, k, v, o, g, omega, a, b, chunk) ->
         (dq, dk, dv); softmax family: (q, k, v, o, lse, omega, chunk)
         -> (dq, dk, dv); ssd family: (q, k, v, log_decay, o, omega,
         chunk) -> (dq, dk, dv, dlog_decay).  None means "fall back" —
         to the xla backward for linear/ssd, to autodiff for softmax
         (the oracles have no analytic backward).
    fwd_res: softmax family only: (q, k, v, chunk) -> (o, lse), the
         causal forward that also returns the logsumexp residual the
         paired bwd recomputes probabilities from.  Required whenever
         bwd is set on a softmax impl.
    """

    family: str
    name: str
    fwd: Callable
    bwd: Optional[Callable] = None
    fwd_res: Optional[Callable] = None


_KERNELS: dict[tuple[str, str], KernelImpl] = {}


def register_kernel(family: str, name: str, *, fwd, bwd=None,
                    fwd_res=None) -> KernelImpl:
    impl = KernelImpl(family=family, name=name, fwd=fwd, bwd=bwd,
                      fwd_res=fwd_res)
    _KERNELS[(family, name)] = impl
    return impl


def kernel_names(family: str) -> list[str]:
    return sorted(n for (f, n) in _KERNELS if f == family)


def get_kernel(family: str, name: str) -> KernelImpl:
    resolved = default_backend() if name == "auto" else name
    impl = _KERNELS.get((family, resolved))
    if impl is None:
        raise ValueError(
            f"unknown kernel impl {name!r} for the {family!r} family; "
            f"registered: {kernel_names(family)} (plus 'auto')")
    return impl


# ---------------------------------------------------------------------------
# Linear family impls
# ---------------------------------------------------------------------------

def _linear_xla_fwd(q, k, v, a, b, chunk):
    chunk = _tile("linear", "xla", "fwd", _attn_shape(q, k), q.dtype,
                  "chunk", chunk)
    o, g, _ = _chunked.la_fwd_chunked(q, k, v, a, b, chunk)
    return o, g


def _linear_xla_bwd(q, k, v, o, g, omega, a, b, chunk):
    chunk = _tile("linear", "xla", "bwd", _attn_shape(q, k), q.dtype,
                  "chunk", chunk)
    return _chunked.la_bwd_chunked(q, k, v, o, g, omega, a, b, chunk)


def _linear_pallas_fwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k, v, a, b, chunk):
        from repro.kernels import linear_attention as _pl
        chunk = _tile("linear", impl, "fwd", _attn_shape(q, k), q.dtype,
                      "chunk", chunk)
        return _pl.la_fwd_pallas(q, k, v, a, b, chunk, interpret=interpret)
    return fwd


def _linear_pallas_bwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def bwd(q, k, v, o, g, omega, a, b, chunk):
        from repro.kernels import linear_attention as _pl
        chunk = _tile("linear", impl, "bwd", _attn_shape(q, k), q.dtype,
                      "chunk", chunk)
        return _pl.la_bwd_pallas(q, k, v, o, g, omega, a, b, chunk,
                                 interpret=interpret)
    return bwd


def _linear_ref_fwd(q, k, v, a, b, chunk):
    o = _ref.la_ref(q, k, v, a, b, causal=True)
    # oracle recomputes g for residuals — grouped einsum over a
    # (b, hkv, g, ...) view of q, no KV head expansion
    bq, h, n, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(bq, hkv, h // hkv, n, d).astype(jnp.float32)
    s = jnp.einsum("bkgid,bkjd->bkgij", qg, k.astype(jnp.float32))
    w = a + b * s
    w = jnp.where(jnp.tril(jnp.ones((n, n), bool)), w, 0.0)
    return o, w.sum(-1).reshape(bq, h, n)


register_kernel("linear", "xla", fwd=_linear_xla_fwd,
                bwd=_linear_xla_bwd)
register_kernel("linear", "pallas", fwd=_linear_pallas_fwd(False),
                bwd=_linear_pallas_bwd(False))
register_kernel("linear", "pallas_interpret", fwd=_linear_pallas_fwd(True),
                bwd=_linear_pallas_bwd(True))
register_kernel("linear", "ref", fwd=_linear_ref_fwd)  # bwd: xla fallback


# ---------------------------------------------------------------------------
# Softmax family impls
# ---------------------------------------------------------------------------

def _softmax_xla_fwd(q, k, v, causal, chunk, q_offset=None):
    chunk = _tile("softmax", "xla", "fwd", _attn_shape(q, k), q.dtype,
                  "chunk", chunk)
    return _softmax.softmax_chunked(q, k, v, causal=causal, chunk=chunk,
                                    q_offset=q_offset)


def _flash_blocks(impl, op, q, k):
    """block_q/block_k overrides for the flash kernels ({} on a miss —
    the kernel entry points then use kernels.defaults)."""
    tiles = tuned_tiles("softmax", impl, op, _attn_shape(q, k), q.dtype)
    return {p: tiles[p] for p in ("block_q", "block_k") if p in tiles}


def _softmax_pallas_fwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k, v, causal, chunk, q_offset=None):
        from repro.kernels import flash_attention as _fl
        if not causal:
            # noncausal (encoder / cross) stays on the XLA scan; the
            # flash grid is causal-trimmed by construction
            return _softmax.softmax_chunked(q, k, v, causal=False,
                                            chunk=chunk)
        # GQA-native and q_offset-native: KV BlockSpecs index by
        # head // group (no H/Hkv-fold copy), per-slot offsets stream in
        # via scalar prefetch (serving continuation prefill)
        return _fl.flash_attention_pallas(q, k, v, q_offset=q_offset,
                                          interpret=interpret,
                                          **_flash_blocks(impl, "fwd",
                                                          q, k))
    return fwd


def _softmax_pallas_fwd_res(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd_res(q, k, v, chunk):
        from repro.kernels import flash_attention as _fl
        return _fl.flash_attention_pallas(q, k, v, interpret=interpret,
                                          return_lse=True,
                                          **_flash_blocks(impl, "fwd",
                                                          q, k))
    return fwd_res


def _softmax_pallas_bwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def bwd(q, k, v, o, lse, omega, chunk):
        from repro.kernels import flash_attention as _fl
        return _fl.flash_attention_bwd_pallas(q, k, v, o, lse, omega,
                                              interpret=interpret,
                                              **_flash_blocks(impl, "bwd",
                                                              q, k))
    return bwd


def _softmax_ref_fwd(q, k, v, causal, chunk, q_offset=None):
    if q_offset is not None:
        return _softmax.softmax_chunked(q, k, v, causal=causal, chunk=chunk,
                                        q_offset=q_offset)
    return _ref.softmax_ref(q, k, v, causal=causal)


register_kernel("softmax", "xla", fwd=_softmax_xla_fwd)
register_kernel("softmax", "pallas", fwd=_softmax_pallas_fwd(False),
                bwd=_softmax_pallas_bwd(False),
                fwd_res=_softmax_pallas_fwd_res(False))
register_kernel("softmax", "pallas_interpret", fwd=_softmax_pallas_fwd(True),
                bwd=_softmax_pallas_bwd(True),
                fwd_res=_softmax_pallas_fwd_res(True))
register_kernel("softmax", "ref", fwd=_softmax_ref_fwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def softmax_causal(q, k, v, chunk: int = DEFAULT_CHUNK,
                   backend: str = "auto"):
    """Causal softmax attention with the flash custom vjp (training entry).

    Residuals are {q, k, v, o, lse} — O(N D) like the linear family —
    and the backward recomputes per-block probabilities (delta
    precompute, then dq and dk/dv over the causal-trimmed grid).  Only
    reachable for impls that registered a bwd; `softmax_attention`
    routes everything else through autodiff-safe fwd paths.
    """
    return get_kernel("softmax", backend).fwd(q, k, v, True, chunk, None)


def _softmax_causal_fwd(q, k, v, chunk, backend):
    impl = get_kernel("softmax", backend)
    if impl.fwd_res is None or impl.bwd is None:
        raise ValueError(
            f"softmax kernel impl {impl.name!r} has no custom backward "
            f"(fwd_res/bwd); differentiate through softmax_attention — "
            f"it falls back to autodiff for such impls — or pick one of "
            f"{[n for (f, n), i in _KERNELS.items() if f == 'softmax' and i.bwd is not None]}")
    o, lse = impl.fwd_res(q, k, v, chunk)
    return o, (q, k, v, o, lse)


def _softmax_causal_bwd(chunk, backend, res, omega):
    q, k, v, o, lse = res
    return get_kernel("softmax", backend).bwd(q, k, v, o, lse, omega, chunk)


softmax_causal.defvjp(_softmax_causal_fwd, _softmax_causal_bwd)


def softmax_attention(q, k, v, *, causal: bool = True,
                      chunk: int = DEFAULT_CHUNK, backend: str = "auto",
                      q_offset=None):
    """Softmax-baseline attention through the registry.

    q: (B, H, N, D); k, v: (B, Hkv, N, D), Hkv | H.  Differentiable on
    every impl: the xla scan recomputes per-chunk probabilities under
    autodiff, the pallas impls train through `softmax_causal`'s custom
    vjp (flash forward + recomputation-based flash backward).
    q_offset: optional (B,) global position of query 0 per sequence
    (serving continuation prefill against a populated KV cache) — runs
    through the flash kernel's scalar-prefetch offset path on the pallas
    impls, no XLA fallback.
    """
    resolved = default_backend() if backend == "auto" else backend
    impl = get_kernel("softmax", resolved)
    if causal and q_offset is None and impl.bwd is not None:
        return softmax_causal(q, k, v, chunk, resolved)
    return impl.fwd(q, k, v, causal, chunk, q_offset)


# ---------------------------------------------------------------------------
# Softmax-decode family (one token per slot against a contiguous KV cache)
#
# Decode against the batched max_len cache used to live as an inline
# einsum in mixers/softmax.py; registering it here makes contiguous and
# paged decode both registry-dispatched (and parity-testable against
# each other).  Only an xla impl exists — the kernelized decode path IS
# the "paged" family below; impl names without a softmax_decode entry
# fall back to xla in `softmax_decode`.
# ---------------------------------------------------------------------------

def _softmax_decode_xla(q, k, v, lengths):
    """q: (B, H, 1, D); k, v: (B, Hkv, S, D); lengths: (B,) valid keys
    per slot (the just-written token included).  Grouped-native, f32
    accumulation, row-max-subtracting softmax."""
    b, hkv, s, d = k.shape
    h = q.shape[1]
    g = h // hkv
    mask_j = (jax.lax.broadcasted_iota(jnp.int32, (b, s), 1)
              < lengths[:, None])                          # (B, S)
    qg = q.reshape(b, hkv, g, 1, d).astype(jnp.float32)
    s_ = jnp.einsum("bhgid,bhjd->bhgij", qg, k.astype(jnp.float32),
                    preferred_element_type=jnp.float32) / d ** 0.5
    s_ = jnp.where(mask_j[:, None, None, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhgij,bhjd->bhgid", p, v.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, 1, d).astype(q.dtype)


register_kernel("softmax_decode", "xla", fwd=_softmax_decode_xla)
register_kernel("softmax_decode", "ref", fwd=_softmax_decode_xla)


def softmax_decode(q, k, v, lengths, *, backend: str = "auto"):
    """Contiguous-cache softmax decode through the registry.

    Impl names with no softmax_decode entry (the pallas flash impls are
    prefill/train kernels) run the xla impl — decode through a Pallas
    kernel is the paged path (`paged_attention`).
    """
    resolved = default_backend() if backend == "auto" else backend
    impl = _KERNELS.get(("softmax_decode", resolved))
    if impl is None:
        impl = get_kernel("softmax_decode", "xla")
    return impl.fwd(q, k, v, lengths)


# ---------------------------------------------------------------------------
# Paged family (serving decode over a paged KV cache — docs/paged_kv.md)
#
# fwd: (q, k_pages, v_pages, page_table, lengths) -> o.  Inference-only
# (no bwd): decode never trains.  The pallas impls gather K/V pages
# through a scalar-prefetched page table; xla/ref gather then softmax.
# ---------------------------------------------------------------------------

def _paged_xla_fwd(q, k_pages, v_pages, page_table, lengths):
    from repro.kernels import paged_attention as _pg
    return _pg.paged_attention_xla(q, k_pages, v_pages, page_table, lengths)


def _paged_shape(q, k_pages, page_table) -> dict:
    ps = k_pages.shape[2]
    return {"b": q.shape[0], "h": q.shape[1], "hkv": k_pages.shape[1],
            "n": page_table.shape[1] * ps, "d": q.shape[3],
            "page_size": ps}


def _paged_pallas_fwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k_pages, v_pages, page_table, lengths):
        from repro.kernels import defaults as _defaults
        from repro.kernels import paged_attention as _pg
        ppb = _tile("paged", impl, "fwd",
                    _paged_shape(q, k_pages, page_table), q.dtype,
                    "pages_per_block",
                    _defaults.DEFAULT_TILES["paged"]["pages_per_block"])
        return _pg.paged_attention_pallas(q, k_pages, v_pages, page_table,
                                          lengths, pages_per_block=ppb,
                                          interpret=interpret)
    return fwd


register_kernel("paged", "xla", fwd=_paged_xla_fwd)
register_kernel("paged", "pallas", fwd=_paged_pallas_fwd(False))
register_kernel("paged", "pallas_interpret", fwd=_paged_pallas_fwd(True))
register_kernel("paged", "ref", fwd=_paged_xla_fwd)


def paged_attention(q, k_pages, v_pages, page_table, lengths, *,
                    backend: str = "auto"):
    """Paged-KV decode through the registry (one query token per slot).

    q: (B, H, 1, D); k_pages/v_pages: (P, Hkv, ps, D) shared arenas;
    page_table: (B, Pmax) int32; lengths: (B,) int32.  cfg.la.backend
    picks the impl like every other family ("auto": pallas on TPU).
    """
    return get_kernel("paged", backend).fwd(q, k_pages, v_pages,
                                            page_table, lengths)


# ---------------------------------------------------------------------------
# SSD family impls (Mamba-2 / decay-gated LA — paper Appendix B, Table 3)
# ---------------------------------------------------------------------------

def _ssd_shape(q, v) -> dict:
    # q, k: (B, G, N, Dk) shared per group; v carries the true head count
    return {"b": q.shape[0], "h": v.shape[1], "hkv": q.shape[1],
            "n": q.shape[2], "d": q.shape[3]}


def _ssd_xla_fwd(q, k, v, log_decay, chunk):
    chunk = _tile("ssd", "xla", "fwd", _ssd_shape(q, v), q.dtype,
                  "chunk", chunk)
    o, _ = _ssd.ssd_fwd_chunked(q, k, v, log_decay, chunk=chunk)
    return o


def _ssd_xla_bwd(q, k, v, log_decay, o, omega, chunk):
    chunk = _tile("ssd", "xla", "bwd", _ssd_shape(q, v), q.dtype,
                  "chunk", chunk)
    return _ssd.ssd_bwd_chunked(q, k, v, log_decay, o, omega, chunk)


def _ssd_pallas_fwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k, v, log_decay, chunk):
        from repro.kernels import ssd as _kssd
        chunk = _tile("ssd", impl, "fwd", _ssd_shape(q, v), q.dtype,
                      "chunk", chunk)
        return _kssd.ssd_fwd_pallas(q, k, v, log_decay, chunk=chunk,
                                    interpret=interpret)
    return fwd


def _ssd_pallas_bwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def bwd(q, k, v, log_decay, o, omega, chunk):
        from repro.kernels import ssd as _kssd
        chunk = _tile("ssd", impl, "bwd", _ssd_shape(q, v), q.dtype,
                      "chunk", chunk)
        return _kssd.ssd_bwd_pallas(q, k, v, log_decay, o, omega,
                                    chunk=chunk, interpret=interpret)
    return bwd


def _ssd_ref_fwd(q, k, v, log_decay, chunk):
    # the oracle is grouped-native: shared q/k heads stay (B, G, N, Dk)
    return _ref.ssd_ref(q, k, v, log_decay)


register_kernel("ssd", "xla", fwd=_ssd_xla_fwd, bwd=_ssd_xla_bwd)
register_kernel("ssd", "pallas", fwd=_ssd_pallas_fwd(False),
                bwd=_ssd_pallas_bwd(False))
register_kernel("ssd", "pallas_interpret", fwd=_ssd_pallas_fwd(True),
                bwd=_ssd_pallas_bwd(True))
register_kernel("ssd", "ref", fwd=_ssd_ref_fwd)  # bwd: xla fallback


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ssd_causal(q, k, v, log_decay,
               chunk: int = DEFAULT_TILES["ssd"]["chunk"],
               backend: str = "auto"):
    """SSD (Mamba-2) with the analytic O(N D) backward (training entry).

    q, k: (B, G, N, Dk) with G | H; v: (B, H, N, Dv); log_decay:
    (B, H, N) <= 0.  `backend` selects the "ssd"-family KernelImpl, so
    cfg.la.backend picks the Mamba-2 impl through the same registry as
    the linear/softmax families ("auto": pallas on TPU, else xla).
    """
    return get_kernel("ssd", backend).fwd(q, k, v, log_decay, chunk)


def _ssd_causal_fwd(q, k, v, log_decay, chunk, backend):
    o = get_kernel("ssd", backend).fwd(q, k, v, log_decay, chunk)
    return o, (q, k, v, log_decay, o)


def _ssd_causal_bwd(chunk, backend, res, omega):
    q, k, v, log_decay, o = res
    impl = get_kernel("ssd", backend)
    bwd = impl.bwd or _ssd.ssd_bwd_chunked
    return bwd(q, k, v, log_decay, o, omega, chunk)


ssd_causal.defvjp(_ssd_causal_fwd, _ssd_causal_bwd)


# ---------------------------------------------------------------------------
# GLA family impls (decay-gated normalized LA — ROADMAP "decay-gated LA";
# core/gla.py has the math, kernels/gla.py the Pallas fwd+bwd)
#
# fwd: (q, k, v, log_decay, a, b, chunk) -> (o, g); bwd: (q, k, v,
# log_decay, o, g, omega, a, b, chunk) -> (dq, dk, dv, dld).  None bwd
# falls back to the xla backward like the linear family.
# ---------------------------------------------------------------------------

def _gla_xla_fwd(q, k, v, log_decay, a, b, chunk):
    chunk = _tile("gla", "xla", "fwd", _attn_shape(q, k), q.dtype,
                  "chunk", chunk)
    o, g, _ = _gla.gla_fwd_chunked(q, k, v, log_decay, a, b, chunk)
    return o, g


def _gla_xla_bwd(q, k, v, log_decay, o, g, omega, a, b, chunk):
    chunk = _tile("gla", "xla", "bwd", _attn_shape(q, k), q.dtype,
                  "chunk", chunk)
    return _gla.gla_bwd_chunked(q, k, v, log_decay, o, g, omega, a, b,
                                chunk)


def _gla_pallas_fwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k, v, log_decay, a, b, chunk):
        from repro.kernels import gla as _pg
        chunk = _tile("gla", impl, "fwd", _attn_shape(q, k), q.dtype,
                      "chunk", chunk)
        return _pg.gla_fwd_pallas(q, k, v, log_decay, a, b, chunk,
                                  interpret=interpret)
    return fwd


def _gla_pallas_bwd(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def bwd(q, k, v, log_decay, o, g, omega, a, b, chunk):
        from repro.kernels import gla as _pg
        chunk = _tile("gla", impl, "bwd", _attn_shape(q, k), q.dtype,
                      "chunk", chunk)
        return _pg.gla_bwd_pallas(q, k, v, log_decay, o, g, omega, a, b,
                                  chunk, interpret=interpret)
    return bwd


def _gla_ref_fwd(q, k, v, log_decay, a, b, chunk):
    # the oracle computes its own normalizer — one masking convention
    return _ref.gla_ref(q, k, v, log_decay, a, b, return_g=True)


register_kernel("gla", "xla", fwd=_gla_xla_fwd, bwd=_gla_xla_bwd)
register_kernel("gla", "pallas", fwd=_gla_pallas_fwd(False),
                bwd=_gla_pallas_bwd(False))
register_kernel("gla", "pallas_interpret", fwd=_gla_pallas_fwd(True),
                bwd=_gla_pallas_bwd(True))
register_kernel("gla", "ref", fwd=_gla_ref_fwd)  # bwd: xla fallback


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def gla_causal(q, k, v, log_decay, a: float = 1.0, b: float = 1.0,
               chunk: int = DEFAULT_CHUNK, backend: str = "auto"):
    """Causal decay-gated normalized LA (training entry).

    q: (B, H, N, D); k, v: (B, Hkv, N, D), Hkv | H; log_decay:
    (B, Hkv, N) <= 0.  Residuals are {q, k, v, ld, o, g} — O(N D) —
    and gradients flow to q, k, v AND log_decay (the gate trains).
    `backend` selects the "gla"-family KernelImpl like every other
    family ("auto": pallas on TPU, else xla).
    """
    o, _ = get_kernel("gla", backend).fwd(q, k, v, log_decay, a, b, chunk)
    return o


def _gla_causal_fwd(q, k, v, log_decay, a, b, chunk, backend):
    o, g = get_kernel("gla", backend).fwd(q, k, v, log_decay, a, b, chunk)
    return o, (q, k, v, log_decay, o, g)


def _gla_causal_bwd(a, b, chunk, backend, res, omega):
    q, k, v, log_decay, o, g = res
    impl = get_kernel("gla", backend)
    bwd = impl.bwd or _gla.gla_bwd_chunked
    return bwd(q, k, v, log_decay, o, g, omega, a, b, chunk)


gla_causal.defvjp(_gla_causal_fwd, _gla_causal_bwd)


def gla_prefill(q, k, v, log_decay, a: float = 1.0, b: float = 1.0,
                chunk: int = DEFAULT_CHUNK,
                state: GLAState | None = None):
    """Causal GLA that also returns the decayed recurrent state.

    Inference-only (no custom grad needed).  Returns (o, GLAState).
    """
    o, _, st = _gla.gla_fwd_chunked(q, k, v, log_decay, a, b, chunk,
                                    state=state)
    return o, st


def gla_decode_step(state: GLAState, q, k, v, log_decay, a: float = 1.0,
                    b: float = 1.0):
    """One-token GLA decode: O(D^2), context enters only via the state."""
    return _gla.gla_decode_step(state, q, k, v, log_decay, a, b)


# ---------------------------------------------------------------------------
# Fused-decode families (kernels/decode_fused.py — ROADMAP "fused
# epilogues"): one Pallas kernel per decode step that keeps the
# normalizer / finalize divide (and GLA's gate, and the GQA head-fold)
# inside the kernel.  The xla/ref impls ARE the unfused compositions,
# so the fallback is byte-identical by construction; mixers route here
# by capability flag (cfg.la.fused_decode).  Decode never trains: no
# bwd on any of these families.
# ---------------------------------------------------------------------------

def _la_decode_unfused(state, q, k, v, a, b):
    return _chunked.la_decode_step(state, q, k, v, a, b)


def _la_decode_fused_pallas(interpret):
    def fwd(state, q, k, v, a, b):
        from repro.kernels import decode_fused as _df
        s, p, o = _df.la_decode_fused_pallas(state.s, state.p, q, k, v,
                                             a, b, interpret=interpret)
        return LAState(s, p), o
    return fwd


register_kernel("linear_decode_fused", "xla", fwd=_la_decode_unfused)
register_kernel("linear_decode_fused", "ref", fwd=_la_decode_unfused)
register_kernel("linear_decode_fused", "pallas",
                fwd=_la_decode_fused_pallas(False))
register_kernel("linear_decode_fused", "pallas_interpret",
                fwd=_la_decode_fused_pallas(True))


def la_decode_step_fused(state: LAState, q, k, v, a: float = 1.0,
                         b: float = 1.0, *, backend: str = "auto"):
    """One-token LA decode through the fused registry family.

    Same contract as `la_decode_step`; the pallas impls run the state
    update, q·S, normalizer dot, and divide in ONE kernel with the
    state donated in place (input_output_aliases), the xla/ref impls
    are the unfused composition itself.
    """
    return get_kernel("linear_decode_fused", backend).fwd(
        state, q, k, v, a, b)


def _gla_decode_unfused(state, q, k, v, log_decay, a, b):
    return _gla.gla_decode_step(state, q, k, v, log_decay, a, b)


def _gla_decode_fused_pallas(interpret):
    def fwd(state, q, k, v, log_decay, a, b):
        from repro.kernels import decode_fused as _df
        s, p, o = _df.gla_decode_fused_pallas(state.s, state.p, q, k, v,
                                              log_decay, a, b,
                                              interpret=interpret)
        return GLAState(s, p), o
    return fwd


register_kernel("gla_decode_fused", "xla", fwd=_gla_decode_unfused)
register_kernel("gla_decode_fused", "ref", fwd=_gla_decode_unfused)
register_kernel("gla_decode_fused", "pallas",
                fwd=_gla_decode_fused_pallas(False))
register_kernel("gla_decode_fused", "pallas_interpret",
                fwd=_gla_decode_fused_pallas(True))


def gla_decode_step_fused(state: GLAState, q, k, v, log_decay,
                          a: float = 1.0, b: float = 1.0, *,
                          backend: str = "auto"):
    """One-token GLA decode through the fused registry family: gate,
    state update, q·S, and normalizer divide in one kernel."""
    return get_kernel("gla_decode_fused", backend).fwd(
        state, q, k, v, log_decay, a, b)


def _softmax_decode_fused_shape(q, k) -> dict:
    return {"b": q.shape[0], "h": q.shape[1], "hkv": k.shape[1],
            "n": k.shape[2], "d": q.shape[3]}


def _softmax_decode_fused_pallas(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k, v, lengths):
        from repro.kernels import decode_fused as _df
        from repro.kernels import defaults as _defaults
        bk = _tile("softmax_decode_fused", impl, "fwd",
                   _softmax_decode_fused_shape(q, k), q.dtype, "block_k",
                   _defaults.DEFAULT_TILES["softmax_decode_fused"]["block_k"])
        return _df.softmax_decode_fused_pallas(q, k, v, lengths,
                                               block_k=bk,
                                               interpret=interpret)
    return fwd


register_kernel("softmax_decode_fused", "xla", fwd=_softmax_decode_xla)
register_kernel("softmax_decode_fused", "ref", fwd=_softmax_decode_xla)
register_kernel("softmax_decode_fused", "pallas",
                fwd=_softmax_decode_fused_pallas(False))
register_kernel("softmax_decode_fused", "pallas_interpret",
                fwd=_softmax_decode_fused_pallas(True))


def softmax_decode_fused(q, k, v, lengths, *, backend: str = "auto"):
    """Contiguous-cache softmax decode through the fused family.

    Unlike `softmax_decode` (xla-only; pallas names fall back), the
    fused family HAS a Pallas kernel for the contiguous cache — online
    softmax over block_k-key blocks with the finalize divide and the
    GQA head-fold inside.  A length-0 slot yields zeros on the pallas
    impls (paged-family semantics); the xla/ref impls are byte-
    identical to `softmax_decode`.
    """
    return get_kernel("softmax_decode_fused", backend).fwd(
        q, k, v, lengths)


def _paged_decode_fused_pallas(interpret):
    impl = "pallas_interpret" if interpret else "pallas"

    def fwd(q, k_pages, v_pages, page_table, lengths):
        from repro.kernels import decode_fused as _df
        from repro.kernels import defaults as _defaults
        ppb = _tile("paged_decode_fused", impl, "fwd",
                    _paged_shape(q, k_pages, page_table), q.dtype,
                    "pages_per_block",
                    _defaults.DEFAULT_TILES["paged_decode_fused"]["pages_per_block"])
        return _df.paged_decode_fused_pallas(q, k_pages, v_pages,
                                             page_table, lengths,
                                             pages_per_block=ppb,
                                             interpret=interpret)
    return fwd


register_kernel("paged_decode_fused", "xla", fwd=_paged_xla_fwd)
register_kernel("paged_decode_fused", "ref", fwd=_paged_xla_fwd)
register_kernel("paged_decode_fused", "pallas",
                fwd=_paged_decode_fused_pallas(False))
register_kernel("paged_decode_fused", "pallas_interpret",
                fwd=_paged_decode_fused_pallas(True))


def paged_attention_fused(q, k_pages, v_pages, page_table, lengths, *,
                          backend: str = "auto"):
    """Paged-KV decode through the fused family (GQA head-folded grid:
    each arena page is DMA'd once per KV head, not once per query
    head).  Same contract as `paged_attention`."""
    return get_kernel("paged_decode_fused", backend).fwd(
        q, k_pages, v_pages, page_table, lengths)


# ---------------------------------------------------------------------------
# Linear family entry points (custom vjp lives here, once)
# ---------------------------------------------------------------------------

def _fwd_dispatch(q, k, v, a, b, chunk, backend):
    return get_kernel("linear", backend).fwd(q, k, v, a, b, chunk)


def _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend):
    impl = get_kernel("linear", backend)
    bwd = impl.bwd or _chunked.la_bwd_chunked
    return bwd(q, k, v, o, g, omega, a, b, chunk)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def la_causal(q, k, v, a: float = 1.0, b: float = 1.0,
              chunk: int = DEFAULT_CHUNK, backend: str = "auto"):
    """Causal normalized linear attention (paper Eqs. 4-9).

    q: (B, H, N, D); k, v: (B, Hkv, N, D), Hkv | H.  Returns (B, H, N, D).
    """
    o, _ = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o


def _la_causal_fwd(q, k, v, a, b, chunk, backend):
    o, g = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o, (q, k, v, o, g)


def _la_causal_bwd(a, b, chunk, backend, res, omega):
    q, k, v, o, g = res
    dq, dk, dv = _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend)
    return dq, dk, dv


la_causal.defvjp(_la_causal_fwd, _la_causal_bwd)


def la_prefill(q, k, v, a: float = 1.0, b: float = 1.0,
               chunk: int = DEFAULT_CHUNK, state: LAState | None = None):
    """Causal LA that also returns the recurrent state for decode.

    Inference-only (no custom grad needed).  Returns (o, LAState).
    """
    o, _, st = _chunked.la_fwd_chunked(q, k, v, a, b, chunk, state=state)
    return o, st


# ---------------------------------------------------------------------------
# Learnable kernel coefficients (paper §2.2: "the coefficients either as
# the Taylor expansion of the exponential or as learnable parameters").
#
# f and g are LINEAR in (a, b): f = a·F1 + b·F2, g = a·G1 + b·G2 with
# F1 = cumsum(v), G1_i = i, and F2/G2 recoverable from the residuals
# (F2 = (o·g − a·F1)/b).  Hence
#     ∂o/∂a = (F1 − o·G1)/g        (one O(N·D) cumsum)
#     ∂o/∂b = −(a/b)·∂o/∂a         (o depends only on a/b, so
#                                    a·da + b·db = 0 exactly)
# — learnable coefficients cost one cumsum + a reduction on top of the
# paper's analytic backward.
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def la_causal_learnable(q, k, v, a, b, chunk: int = DEFAULT_CHUNK,
                        backend: str = "auto"):
    """Causal normalized LA with DIFFERENTIABLE scalar coefficients.

    a, b: scalar jnp arrays (learnable parameters).  Same output as
    la_causal; gradients flow to q, k, v, a and b.
    """
    o, _ = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o


def _la_learn_fwd(q, k, v, a, b, chunk, backend):
    o, g = _fwd_dispatch(q, k, v, a, b, chunk, backend)
    return o, (q, k, v, o, g, a, b)


def _la_learn_bwd(chunk, backend, res, omega):
    q, k, v, o, g, a, b = res
    dq, dk, dv = _bwd_dispatch(q, k, v, o, g, omega, a, b, chunk, backend)
    f32 = jnp.float32
    kk = _ref.expand_kv(v, q.shape[1]) if v.shape[1] != q.shape[1] else v
    f1 = jnp.cumsum(kk.astype(f32), axis=2)              # (B, H, N, D)
    n = q.shape[2]
    g1 = jnp.arange(1, n + 1, dtype=f32)[None, None, :, None]
    do_da = (f1 - o.astype(f32) * g1) / g[..., None]
    da = jnp.sum(omega.astype(f32) * do_da)
    db = -(a.astype(f32) / b.astype(f32)) * da
    return dq, dk, dv, da.astype(a.dtype), db.astype(b.dtype)


la_causal_learnable.defvjp(_la_learn_fwd, _la_learn_bwd)
