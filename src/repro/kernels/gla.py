"""Pallas TPU kernels — decay-gated (GLA) normalized LA, fwd AND bwd.

The linear-attention kernel scheme (kernels/linear_attention.py: B*H
outer-block parallelism, sequential chunk axis, f32 VMEM scratch state,
ones-column V augmentation fusing numerator and denominator into one
MXU contraction) with the SSD kernels' log-space decay carried across
chunks:

  forward      state (Dk, Dv+1) scratch; chunk update
               S <- exp(total) S + (exp(total - cl) k)^T V'
  grad Q       forward scan carrying the same decayed state
  grad K / V'  reverse scan carrying U = suffix sum of decayed
               qaug (x) [om_hat, -h]; the augmented dV' column feeds the
               log-decay gradient (computed by the caller:
               dcl = -V'.dV', dld = reverse cumsum)

Grouped-query attention reads k / v / log_decay through hi // group
index maps — no per-head repetition in HBM; the grad-K/V grid runs at
Hkv with the group's query heads folded into the row axis.

Validated against kernels/ref.gla_ref and core/gla.py in interpret mode
(this container is CPU-only; TPU is the lowering target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import safe_div
from repro.kernels.defaults import DEFAULT_TILES

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

F32 = jnp.float32
_CHUNK = DEFAULT_TILES["gla"]["chunk"]


def _pad_seq(x, n_pad, axis: int = 2):
    if x.shape[axis] == n_pad:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, n_pad - x.shape[axis])
    return jnp.pad(x, w)


def _tile_rows(x, g: int):
    """(C,) -> (g*C,) — the grouped query heads folded into rows."""
    return jnp.broadcast_to(x[None, :], (g, x.shape[0])).reshape(-1)


def _decay_tri(cl_rows, cl_cols, row_mod: int | None = None):
    """Masked decay matrix D[i, j] = exp(cl_i - cl_j) for i >= j, else 0
    (`row_mod` folds grouped query rows: the causal test is i % c >= j).
    The exponent is clamped at 0 — above-diagonal differences are
    positive and would overflow under strong decay before the mask
    zeroes them."""
    r, c = cl_rows.shape[0], cl_cols.shape[0]
    ii = lax.broadcasted_iota(jnp.int32, (r, c), 0)
    if row_mod is not None:
        ii = ii % row_mod
    jj = lax.broadcasted_iota(jnp.int32, (r, c), 1)
    diff = jnp.minimum(cl_rows[:, None] - cl_cols[None, :], 0.0)
    return jnp.where(ii >= jj, jnp.exp(diff), 0.0)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _gla_fwd_kernel(q_ref, k_ref, v_ref, ld_ref, o_ref, g_ref, s_ref,
                    p_ref, *, a: float, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        p_ref[...] = jnp.zeros_like(p_ref)

    q = q_ref[0, 0].astype(F32)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)
    c = q.shape[0]
    dv = v.shape[1]
    vaug = jnp.concatenate([v, jnp.ones((c, 1), F32)], axis=1)

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    att = a + b * jnp.dot(q, k.T, preferred_element_type=F32)
    att = att * _decay_tri(cl, cl)
    f = (jnp.dot(att, vaug, preferred_element_type=F32)
         + jnp.exp(cl)[:, None]
         * (a * p_ref[...]
            + b * jnp.dot(q, s_ref[...], preferred_element_type=F32)))
    g = f[:, dv]
    # guarded finalize: with a == 0 padded rows (q = k = v = 0) have
    # g == 0 and the raw divide would NaN the whole run under
    # jax_debug_nans even though the rows are sliced away (same class
    # PR 3 fixed in the flash kernel)
    gd = jnp.where(g == 0.0, 1.0, g)
    o_ref[0, 0] = (f[:, :dv] / gd[:, None]).astype(o_ref.dtype)
    g_ref[0, 0] = g.astype(g_ref.dtype)

    vw = jnp.exp(total - cl)[:, None] * vaug
    s_ref[...] = (jnp.exp(total) * s_ref[...]
                  + jnp.dot(k.T, vw, preferred_element_type=F32))
    p_ref[...] = (jnp.exp(total) * p_ref[...]
                  + jnp.sum(vw, axis=0, keepdims=True))


def gla_fwd_pallas(q, k, v, log_decay, a: float, b: float,
                   chunk: int = _CHUNK, interpret: bool = False):
    """Returns (o, g).  q: (B,H,N,Dk); k,v: (B,Hkv,N,D); ld: (B,Hkv,N)."""
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = h // hkv
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c
    q, k, v = (_pad_seq(x, n_pad) for x in (q, k, v))
    ld = _pad_seq(log_decay, n_pad)

    kernel = functools.partial(_gla_fwd_kernel, a=a, b=b)
    o, g = pl.pallas_call(
        kernel,
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c),
                         lambda bi, hi, ti: (bi, hi // group, ti)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dv), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, n_pad, dv), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, n_pad), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv + 1), F32),
            pltpu.VMEM((1, dv + 1), F32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, ld)
    return o[:, :, :n], g[:, :, :n]


# ---------------------------------------------------------------------------
# Backward — grad Q (forward chunk scan carrying the decayed state)
# ---------------------------------------------------------------------------

def _gla_bwd_q_kernel(k_ref, v_ref, om_ref, h_ref, ld_ref, dq_ref, s_ref,
                      *, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    om = om_ref[0, 0].astype(F32)
    hv = h_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)
    c = k.shape[0]
    vaug = jnp.concatenate([v, jnp.ones((c, 1), F32)], axis=1)
    gmat = jnp.concatenate([om, -hv[:, None]], axis=1)  # [om_hat, -h]

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    sc = jnp.dot(gmat, vaug.T, preferred_element_type=F32)
    sc = sc * _decay_tri(cl, cl)
    dq = (jnp.dot(sc, k, preferred_element_type=F32)
          + jnp.exp(cl)[:, None]
          * jnp.dot(gmat, s_ref[...].T, preferred_element_type=F32))
    dq_ref[0, 0] = (b * dq).astype(dq_ref.dtype)

    vw = jnp.exp(total - cl)[:, None] * vaug
    s_ref[...] = (jnp.exp(total) * s_ref[...]
                  + jnp.dot(k.T, vw, preferred_element_type=F32))


# ---------------------------------------------------------------------------
# Backward — grad K / grad V' (reverse chunk scan)
# ---------------------------------------------------------------------------

def _gla_bwd_kv_kernel(q_ref, k_ref, v_ref, om_ref, h_ref, ld_ref,
                       dk_ref, dva_ref, u_ref, *, a: float, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    g_, c, dk = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = v_ref.shape[3]
    q = q_ref[0].astype(F32).reshape(g_ * c, dk)
    om = om_ref[0].astype(F32).reshape(g_ * c, dv)
    hv = h_ref[0].astype(F32).reshape(g_ * c, 1)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)

    vaug = jnp.concatenate([v, jnp.ones((c, 1), F32)], axis=1)
    gmat = jnp.concatenate([om, -hv], axis=1)              # (G*C, Dv+1)
    u = u_ref[...]

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    e_p = jnp.exp(total - cl)                              # (C,)
    # m[i_fold, p] = exp(cl_{i % c} - cl_p), i % c >= p
    m = _decay_tri(_tile_rows(cl, g_), cl, row_mod=c)

    sc = jnp.dot(gmat, vaug.T, preferred_element_type=F32) * m
    dk_ = (jnp.dot(sc.T, q, preferred_element_type=F32)
           + e_p[:, None] * jnp.dot(vaug, u[:dk, :].T,
                                    preferred_element_type=F32))
    dk_ref[0, 0] = (b * dk_).astype(dk_ref.dtype)

    att = (a + b * jnp.dot(q, k.T, preferred_element_type=F32)) * m
    dva = (jnp.dot(att.T, gmat, preferred_element_type=F32)
           + e_p[:, None] * (b * jnp.dot(k, u[:dk, :],
                                         preferred_element_type=F32)
                             + a * u[dk, :][None, :]))
    dva_ref[0, 0] = dva.astype(dva_ref.dtype)

    qaug = jnp.concatenate([q, jnp.ones((g_ * c, 1), F32)], axis=1)
    cl_fold = _tile_rows(cl, g_)
    u_ref[...] = (jnp.exp(total) * u_ref[...]
                  + jnp.dot((jnp.exp(cl_fold)[:, None] * qaug).T, gmat,
                            preferred_element_type=F32))


def gla_bwd_pallas(q, k, v, log_decay, o, g, omega, a: float, b: float,
                   chunk: int = _CHUNK, interpret: bool = False):
    """Analytic gated backward from residuals {q, k, v, ld, o, g}.

    Returns (dq, dk, dv, dlog_decay)."""
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = h // hkv
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    om_hat = safe_div(omega.astype(F32), g[..., None])
    h_vec = jnp.sum(o.astype(F32) * om_hat, axis=-1)  # (B,H,N)
    q, k, v = (_pad_seq(x, n_pad) for x in (q, k, v))
    om_hat = _pad_seq(om_hat, n_pad)
    h_vec = _pad_seq(h_vec[..., None], n_pad)[..., 0]
    ldp = _pad_seq(log_decay.astype(F32), n_pad)

    dq = pl.pallas_call(
        functools.partial(_gla_bwd_q_kernel, b=b),
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
            pl.BlockSpec((1, 1, c),
                         lambda bi, hi, ti: (bi, hi // group, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, dk),
                               lambda bi, hi, ti: (bi, hi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, dk), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv + 1), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(k, v, om_hat, h_vec, ldp)

    rev = lambda ti: t - 1 - ti  # noqa: E731 — reverse chunk iteration
    dk_o, dva = pl.pallas_call(
        functools.partial(_gla_bwd_kv_kernel, a=a, b=b),
        grid=(bsz, hkv, t),
        in_specs=[
            pl.BlockSpec((1, group, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, group, c, dv),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, group, c),
                         lambda bi, hi, ti: (bi, hi, rev(ti))),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, rev(ti))),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv + 1),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, dk), k.dtype),
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, dv + 1), F32),
        ],
        scratch_shapes=[pltpu.VMEM((dk + 1, dv + 1), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, om_hat, h_vec, ldp)

    dk_o, dva = dk_o[:, :, :n], dva[:, :, :n]
    # log-decay gradient from the augmented dV' column:
    # dcl = -V'.dV', dld = reverse cumsum (see core/gla.py)
    vaug = jnp.concatenate(
        [v[:, :, :n].astype(F32),
         jnp.ones(v[:, :, :n].shape[:-1] + (1,), F32)], -1)
    dcl = -jnp.sum(vaug * dva, axis=-1)
    dld = jnp.cumsum(dcl[..., ::-1], axis=-1)[..., ::-1]
    return (dq[:, :, :n], dk_o,
            dva[..., :dv].astype(v.dtype), dld.astype(log_decay.dtype))
