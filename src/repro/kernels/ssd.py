"""Pallas TPU kernels — chunked SSD (Mamba-2) forward AND backward.

Same grid/scratch scheme as kernels/linear_attention.py with a per-token
scalar decay carried in log space.  The backward implements the analytic
gradient of core/ssd.py (the paper's Eqs. 19-21 discipline extended to
the decay-gated mixer):

    dq_i = S_i @ Om_i                 (forward chunk scan, same state S)
    dk_n = U_n @ v_n, dv_n = U_n^T k_n (reverse scan, U = decayed q Om^T)
    dld  = reverse-cumsum(Om.o - v.dv) (computed by the caller)

Grouped q/k (G | H) is read through hi // group index maps, so the
shared Mamba-2 B/C projections are never repeated in HBM.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

from repro.kernels.defaults import DEFAULT_TILES

F32 = jnp.float32
_CHUNK = DEFAULT_TILES["ssd"]["chunk"]


def _ssd_kernel(q_ref, k_ref, v_ref, ld_ref, o_ref, s_ref):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0, 0].astype(F32)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)
    c = q.shape[0]

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    att = jnp.dot(q, k.T, preferred_element_type=F32)
    diff = cl[:, None] - cl[None, :]
    ii = lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = att * jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    o = (jnp.dot(att, v, preferred_element_type=F32)
         + jnp.exp(cl)[:, None]
         * jnp.dot(q, s_ref[...], preferred_element_type=F32))
    o_ref[0, 0] = o.astype(o_ref.dtype)

    kw = jnp.exp(total - cl)[:, None] * k
    s_ref[...] = (jnp.exp(total) * s_ref[...]
                  + jnp.dot(kw.T, v, preferred_element_type=F32))


def ssd_fwd_pallas(q, k, v, log_decay, chunk: int = _CHUNK,
                   interpret: bool = False):
    """q, k: (B,G,N,Dk) shared per group (G | H, Mamba-2 style); v:
    (B,H,N,Dv); log_decay: (B,H,N).  Returns o: (B,H,N,Dv).

    The grouped q/k blocks are read through an hi // group index map —
    no per-head repetition is materialized in HBM (same trick as the
    LA kernel's GQA handling).
    """
    bsz, g, n, dk = q.shape
    h = v.shape[1]
    group = h // g
    dv = v.shape[-1]
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    def pad(x):
        if x.shape[2] == n_pad:
            return x
        w = [(0, 0)] * x.ndim
        w[2] = (0, n_pad - x.shape[2])
        return jnp.pad(x, w)

    q, k, v = pad(q), pad(k), pad(v)
    log_decay = pad(log_decay[..., None])[..., 0]

    o = pl.pallas_call(
        _ssd_kernel,
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, dv),
                               lambda bi, hi, ti: (bi, hi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_decay)
    return o[:, :, :n]


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _ssd_bwd_q_kernel(k_ref, v_ref, om_ref, ld_ref, dq_ref, s_ref):
    """Forward scan: dq_i = S_i @ Om_i (per-head partials; the caller
    sums over the group)."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    om = om_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)
    c = k.shape[0]

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    ii = lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jj = lax.broadcasted_iota(jnp.int32, (c, c), 1)
    # w[i, n] = (Om_i . v_n) exp(cl_i - cl_n), n <= i
    p = jnp.dot(om, v.T, preferred_element_type=F32)
    diff = cl[:, None] - cl[None, :]
    w = p * jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    dq = (jnp.dot(w, k, preferred_element_type=F32)
          + jnp.exp(cl)[:, None]
          * jnp.dot(om, s_ref[...].T, preferred_element_type=F32))
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)

    kw = jnp.exp(total - cl)[:, None] * k
    s_ref[...] = (jnp.exp(total) * s_ref[...]
                  + jnp.dot(kw.T, v, preferred_element_type=F32))


def _ssd_bwd_kv_kernel(q_ref, k_ref, v_ref, om_ref, ld_ref, dk_ref, dv_ref,
                       u_ref):
    """Reverse scan: U_n = sum_{i>=n} exp(cl_i - cl_n) q_i Om_i^T;
    dk_n = U_n v_n (group-partial), dv_n = U_n^T k_n."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    q = q_ref[0, 0].astype(F32)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    om = om_ref[0, 0].astype(F32)
    ld = ld_ref[0, 0].astype(F32)
    c = q.shape[0]

    cl = jnp.cumsum(ld)
    total = cl[c - 1]
    e_n = jnp.exp(total - cl)
    nn = lax.broadcasted_iota(jnp.int32, (c, c), 0)
    ii = lax.broadcasted_iota(jnp.int32, (c, c), 1)
    m_hi = jnp.where(ii >= nn, jnp.exp(cl[None, :] - cl[:, None]), 0.0)

    p = jnp.dot(v, om.T, preferred_element_type=F32)      # p[n,i]=Om_i.v_n
    dk = (jnp.dot(p * m_hi, q, preferred_element_type=F32)
          + e_n[:, None] * jnp.dot(v, u_ref[...].T,
                                   preferred_element_type=F32))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)

    s_qk = jnp.dot(k, q.T, preferred_element_type=F32)    # s[n,i]=q_i.k_n
    dv = (jnp.dot(s_qk * m_hi, om, preferred_element_type=F32)
          + e_n[:, None] * jnp.dot(k, u_ref[...],
                                   preferred_element_type=F32))
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)

    qw = jnp.exp(cl)[:, None] * q
    u_ref[...] = (jnp.exp(total) * u_ref[...]
                  + jnp.dot(qw.T, om, preferred_element_type=F32))


def ssd_bwd_pallas(q, k, v, log_decay, o, omega, chunk: int = _CHUNK,
                   interpret: bool = False):
    """Analytic SSD backward on TPU.  q, k: (B,G,N,Dk); v/o/omega:
    (B,H,N,Dv); log_decay: (B,H,N).  Returns (dq, dk, dv, dld) with
    dq/dk group-summed to (B,G,N,Dk)."""
    bsz, g, n, dk = q.shape
    h = v.shape[1]
    group = h // g
    dv_d = v.shape[-1]
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    def pad(x):
        if x.shape[2] == n_pad:
            return x
        w = [(0, 0)] * x.ndim
        w[2] = (0, n_pad - x.shape[2])
        return jnp.pad(x, w)

    qp, kp, vp, omp = pad(q), pad(k), pad(v), pad(omega)
    ldp = pad(log_decay[..., None])[..., 0]

    # dq: per-head partials, grid over H; summed over the group after
    dq_part = pl.pallas_call(
        _ssd_bwd_q_kernel,
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv_d),
                         lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c, dv_d),
                         lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, dk),
                               lambda bi, hi, ti: (bi, hi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, dk), F32),
        scratch_shapes=[pltpu.VMEM((dk, dv_d), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kp, vp, omp, ldp)
    dq = dq_part.reshape(bsz, g, group, n_pad, dk).sum(2)[:, :, :n]

    rev = lambda ti: t - 1 - ti  # noqa: E731
    dk_part, dv_o = pl.pallas_call(
        _ssd_bwd_kv_kernel,
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv_d),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv_d),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, rev(ti))),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv_d),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, n_pad, dk), F32),
            jax.ShapeDtypeStruct((bsz, h, n_pad, dv_d), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv_d), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp, omp, ldp)
    dk_o = dk_part.reshape(bsz, g, group, n_pad, dk).sum(2)[:, :, :n]
    dv_o = dv_o[:, :, :n]

    dcl = (jnp.sum(omega.astype(F32) * o.astype(F32), -1)
           - jnp.sum(v.astype(F32) * dv_o.astype(F32), -1))
    dld = jnp.cumsum(dcl[..., ::-1], axis=-1)[..., ::-1]
    return (dq.astype(q.dtype), dk_o.astype(k.dtype),
            dv_o.astype(v.dtype), dld.astype(log_decay.dtype))
