"""Pallas TPU kernel — causal flash attention forward (baseline).

The paper benchmarks against FlashAttention-2 (Dao, 2024); this is the
TPU analogue used by the benchmark harness: online-softmax with running
max/sum in VMEM scratch, grid (B, H, N/Cq, N/Ck), KV blocks streamed and
skipped above the causal diagonal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, blocks_k: int):
    tq = pl.program_id(2)
    tk = pl.program_id(3)

    @pl.when(tk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cq = q_ref.shape[2]
    ck = k_ref.shape[2]

    @pl.when(tk * ck < (tq + 1) * cq)  # KV block intersects causal triangle
    def _step():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=F32)
        # global causal mask: row tq*cq+i attends to col tk*ck+j iff >=
        ii = tq * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        jj = tk * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        s = jnp.where(ii >= jj, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(tk == blocks_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """Causal softmax attention.  q,k,v: (B,H,N,D) (KV heads pre-expanded)."""
    bsz, h, n, d = q.shape
    scale = (1.0 / d**0.5) if scale is None else scale
    cq, ck = min(block_q, n), min(block_k, n)
    n_pad = -(-n // max(cq, ck)) * max(cq, ck)
    if n_pad != n:
        w = [(0, 0), (0, 0), (0, n_pad - n), (0, 0)]
        # padded keys fall outside every real row's causal window (j > i),
        # so they are masked to -inf; padded query rows are sliced away.
        q, k, v = jnp.pad(q, w), jnp.pad(k, w), jnp.pad(v, w)
    tq, tk = n_pad // cq, n_pad // ck

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, blocks_k=tk),
        grid=(bsz, h, tq, tk),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, ck, d), lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq, d), F32),
            pltpu.VMEM((cq, 1), F32),
            pltpu.VMEM((cq, 1), F32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :n]
