"""Pallas TPU flash-attention subsystem — the softmax baseline's kernels.

The paper benchmarks against FlashAttention-2 (Dao, 2024); this module is
the TPU analogue used by the softmax `KernelImpl` family in
`kernels.ops`, and (since flash v2) a full forward+backward subsystem
rather than a forward-only benchmark artifact:

forward  `flash_attention_pallas`
  * online softmax with running max/sum in VMEM scratch, grid
    (B, H, Nq/Cq, Nk/Ck), KV blocks streamed along the sequential axis;
  * GQA-NATIVE: the KV BlockSpecs index by `head // group`, so grouped
    queries share one streamed KV block — no H/Hkv-fold KV copy is ever
    materialized (memory traffic matches the (B, Hkv, N, D) inputs);
  * per-slot continuation offsets: `q_offset` (B,) rides in via scalar
    prefetch; query row i of slot b sits at global position
    q_offset[b] + i and attends to its whole cached prefix.  KV blocks
    past a slot's causal frontier are clamped to the frontier block in
    the index map — the pipeline re-fetches nothing for them — and their
    compute is skipped, so the KV walk is bounded at the deepest slot's
    frontier instead of the cache length;
  * returns the per-row logsumexp when asked (`return_lse`), the only
    residual the backward needs beyond (q, k, v, o);
  * fully-masked (padded) query rows finalize through a guarded divide:
    `acc / max(l, eps)` never produces NaN before the pad-slice.

backward `flash_attention_bwd_pallas` (GLA-style recomputation, Yang et
al. 2024: store O(N) residuals, recompute probabilities per block)
  * delta precompute kernel: delta_i = sum_d dO_id * O_id;
  * dq kernel over the causal-trimmed (B, H, Tq, Tk) grid, KV blocks
    beyond the diagonal clamped + skipped;
  * dk/dv kernel over (B, Hkv, Tk, Tq) with the group's query heads
    folded into the row axis — grads land directly on the (B, Hkv, N, D)
    KV tensors, again with no head-expansion copy.

The custom-vjp wiring that makes `softmax x pallas` trainable lives in
`kernels.ops` (one place for every family), not here.

Validated against kernels/ref.py and core/softmax.py in interpret mode
(this container is CPU-only; TPU is the lowering target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

from repro.kernels.defaults import DEFAULT_TILES

F32 = jnp.float32
NEG_INF = -1e30
_BQ = DEFAULT_TILES["softmax"]["block_q"]
_BK = DEFAULT_TILES["softmax"]["block_k"]


def _pad_seq(x, n_pad):
    if x.shape[2] == n_pad:
        return x
    w = [(0, 0)] * x.ndim
    w[2] = (0, n_pad - x.shape[2])
    return jnp.pad(x, w)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, scale: float, blocks_k: int):
    bi = pl.program_id(0)
    tq = pl.program_id(2)
    tk = pl.program_id(3)
    off = off_ref[bi]

    @pl.when(tk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cq = q_ref.shape[2]
    ck = k_ref.shape[2]

    # KV block intersects some query's causal window: its first key
    # column tk*ck must not lie beyond the block's deepest query row,
    # which sits at global position off + (tq+1)*cq - 1.
    @pl.when(tk * ck <= off + (tq + 1) * cq - 1)
    def _step():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=F32)
        # causal mask at global positions: query row i of this block is
        # position off + tq*cq + i, key column j is position tk*ck + j
        ii = off + tq * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        jj = tk * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        s = jnp.where(ii >= jj, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(tk == blocks_k - 1)
    def _finalize():
        # guard: a fully-masked (padded) query row accumulates l == 0;
        # dividing by it would put NaN in the rows the caller slices off
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(l_safe))[:, 0]


def flash_attention_pallas(q, k, v, scale: float | None = None,
                           block_q: int = _BQ, block_k: int = _BK,
                           interpret: bool = False, q_offset=None,
                           return_lse: bool = False):
    """Causal flash attention, GQA-native.

    q: (B, H, Nq, D); k, v: (B, Hkv, Nk, D) with Hkv | H — KV heads are
    read through a `head // group` BlockSpec index, never expanded.

    q_offset: optional (B,) int32 — per-sequence global position of
    query row 0 (serving continuation prefill against a populated KV
    cache).  None keeps the training convention (query i is global
    position i + Nk - Nq, shared across the batch).

    Returns o (B, H, Nq, D), plus the f32 logsumexp (B, H, Nq) when
    `return_lse` (the backward's residual).
    """
    bsz, h, nq, d = q.shape
    hkv, nk = k.shape[1], k.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    scale = (1.0 / d**0.5) if scale is None else scale
    cq, ck = min(block_q, nq), min(block_k, nk)
    nq_pad = -(-nq // cq) * cq
    nk_pad = -(-nk // ck) * ck
    # padded keys land beyond every slot's causal frontier (the engine
    # guarantees q_offset + Nq <= Nk), so the global mask drops them;
    # padded query rows are sliced away after the guarded finalize.
    q = _pad_seq(q, nq_pad)
    k, v = _pad_seq(k, nk_pad), _pad_seq(v, nk_pad)
    tq, tk = nq_pad // cq, nk_pad // ck
    if q_offset is None:
        q_offset = jnp.full((bsz,), nk - nq, jnp.int32)
    q_offset = q_offset.astype(jnp.int32)

    def kv_index(bi, hi, qi, ki, off):
        # clamp to the slot's causal frontier block: iterations past it
        # keep the same block index, so the pipeline issues no new DMA
        frontier = (off[bi] + (qi + 1) * cq - 1) // ck
        return (bi, hi // group, jnp.minimum(ki, frontier), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, h, tq, tk),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d),
                         lambda bi, hi, qi, ki, off: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, ck, d), kv_index),
            pl.BlockSpec((1, 1, ck, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cq, d),
                         lambda bi, hi, qi, ki, off: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, cq),
                         lambda bi, hi, qi, ki, off: (bi, hi, qi)),
        ],
        scratch_shapes=[
            pltpu.VMEM((cq, d), F32),
            pltpu.VMEM((cq, 1), F32),
            pltpu.VMEM((cq, 1), F32),
        ],
    )
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, blocks_k=tk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nq_pad, d), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, nq_pad), F32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_offset, q, k, v)
    o, lse = o[:, :, :nq], lse[:, :, :nq]
    return (o, lse) if return_lse else o


# ---------------------------------------------------------------------------
# Backward — delta precompute
# ---------------------------------------------------------------------------

def _delta_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0, 0].astype(F32)
    do = do_ref[0, 0].astype(F32)
    delta_ref[0, 0] = jnp.sum(o * do, axis=1)


# ---------------------------------------------------------------------------
# Backward — dq (recompute P per KV block, causal-trimmed grid)
# ---------------------------------------------------------------------------

def _bwd_q_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                  dq_ref, acc_ref, *, scale: float, blocks_k: int):
    tq = pl.program_id(2)
    tk = pl.program_id(3)

    @pl.when(tk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cq = q_ref.shape[2]
    ck = k_ref.shape[2]

    @pl.when(tk * ck < (tq + 1) * cq)  # KV block intersects the triangle
    def _step():
        q = q_ref[0, 0].astype(F32)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        do = do_ref[0, 0].astype(F32)
        lse = lse_ref[0, 0].astype(F32)
        delta = delta_ref[0, 0].astype(F32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=F32)
        ii = tq * cq + lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
        jj = tk * ck + lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
        p = jnp.where(ii >= jj, jnp.exp(s - lse[:, None]), 0.0)
        dp = jnp.dot(do, v.T, preferred_element_type=F32)
        ds = p * (dp - delta[:, None])
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=F32)

    @pl.when(tk == blocks_k - 1)
    def _finalize():
        dq_ref[0, 0] = (scale * acc_ref[...]).astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# Backward — dk/dv (group's query heads folded into the row axis)
# ---------------------------------------------------------------------------

def _bwd_kv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dk_ref, dv_ref, dk_acc, dv_acc, *, scale: float,
                   blocks_q: int):
    tk = pl.program_id(2)
    tq = pl.program_id(3)

    @pl.when(tq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    g, cq, d = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    ck = k_ref.shape[2]

    @pl.when((tq + 1) * cq > tk * ck)  # q block reaches the KV block
    def _step():
        q = q_ref[0].astype(F32).reshape(g * cq, d)
        do = do_ref[0].astype(F32).reshape(g * cq, d)
        lse = lse_ref[0].astype(F32).reshape(g * cq, 1)
        delta = delta_ref[0].astype(F32).reshape(g * cq, 1)
        k = k_ref[0, 0].astype(F32)
        v = v_ref[0, 0].astype(F32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=F32)
        # row r of the folded (g*cq) axis is local query row r % cq
        ii = tq * cq + (lax.broadcasted_iota(jnp.int32, (g * cq, ck), 0)
                        % cq)
        jj = tk * ck + lax.broadcasted_iota(jnp.int32, (g * cq, ck), 1)
        p = jnp.where(ii >= jj, jnp.exp(s - lse), 0.0)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=F32)
        dp = jnp.dot(do, v.T, preferred_element_type=F32)
        ds = p * (dp - delta)
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=F32)

    @pl.when(tq == blocks_q - 1)
    def _finalize():
        dk_ref[0, 0] = (scale * dk_acc[...]).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, o, lse, do,
                               scale: float | None = None,
                               block_q: int = _BQ, block_k: int = _BK,
                               interpret: bool = False):
    """Recomputation-based flash backward from residuals {q, k, v, o, lse}.

    Training path only (self-attention, Nq == Nk, no q_offset).  Returns
    (dq, dk, dv) with dk/dv in the UNEXPANDED (B, Hkv, N, D) layout —
    the group's query-head contributions are summed inside the kernel.
    """
    bsz, h, n, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = (1.0 / d**0.5) if scale is None else scale
    cq, ck = min(block_q, n), min(block_k, n)
    # both grids tile the SAME padded length, so it must be a common
    # multiple of both block sizes — flooring n_pad // ck with unequal
    # blocks would silently drop whole KV blocks from the gradient
    lcm = cq * ck // math.gcd(cq, ck)
    n_pad = -(-n // lcm) * lcm
    tq, tk = n_pad // cq, n_pad // ck

    q, k, v, o, do = (_pad_seq(x, n_pad) for x in (q, k, v, o, do))
    # padded rows carry do == 0, so any p they recompute contributes 0
    lse = _pad_seq(lse[..., None], n_pad)[..., 0]

    delta = pl.pallas_call(
        _delta_kernel,
        grid=(bsz, h, tq),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, cq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cq),
                               lambda bi, hi, qi: (bi, hi, qi)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad), F32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(o, do)

    def kv_trim(bi, hi, qi, ki):
        # blocks above the diagonal re-use the diagonal block (no DMA)
        return (bi, hi // group, jnp.minimum(ki, ((qi + 1) * cq - 1) // ck),
                0)

    dq = pl.pallas_call(
        functools.partial(_bwd_q_kernel, scale=scale, blocks_k=tk),
        grid=(bsz, h, tq, tk),
        in_specs=[
            pl.BlockSpec((1, 1, cq, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, ck, d), kv_trim),
            pl.BlockSpec((1, 1, ck, d), kv_trim),
            pl.BlockSpec((1, 1, cq, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, cq),
                         lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, cq),
                         lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, cq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((cq, d), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    def q_trim(bi, hi, ki, qi):
        # q blocks strictly above the diagonal contribute nothing: clamp
        # them to the first contributing block so no DMA is issued
        return (bi, hi, jnp.maximum(qi, (ki * ck) // cq), 0)

    def q_trim_vec(bi, hi, ki, qi):
        return (bi, hi, jnp.maximum(qi, (ki * ck) // cq))

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_kv_kernel, scale=scale, blocks_q=tq),
        grid=(bsz, hkv, tk, tq),
        in_specs=[
            pl.BlockSpec((1, group, cq, d), q_trim),
            pl.BlockSpec((1, 1, ck, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, ck, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, group, cq, d), q_trim),
            pl.BlockSpec((1, group, cq), q_trim_vec),
            pl.BlockSpec((1, group, cq), q_trim_vec),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, ck, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, ck, d),
                         lambda bi, hi, ki, qi: (bi, hi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, d), k.dtype),
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, d), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((ck, d), F32),
                        pltpu.VMEM((ck, d), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    return dq[:, :, :n], dk[:, :, :n], dv[:, :, :n]
