"""Pallas TPU kernels for the paper's linear attention.

TPU adaptation of the paper's CUDA kernels (§4, Algorithms 1-4):

  * the per-thread register state (x^(1), x^(2), alpha, beta recurrences)
    becomes f32 VMEM scratch carried across the sequential chunk axis of
    the grid;
  * the paper's "Constant term" and "Linear term" are fused by augmenting
    V with a ones column, so one MXU contraction produces numerator and
    denominator together;
  * the D/L-block warp reduction is unnecessary — the m-contraction lives
    inside a single systolic matmul;
  * coalesced off-chip access becomes BlockSpec HBM->VMEM streaming with
    D on lanes and the token chunk on sublanes.

Grid layout (forward & grad-Q): (B, H, N/C), semantics
("parallel", "parallel", "arbitrary") — B*H is the paper's outer-block
parallelism, the chunk axis is its sequential token loop.  Grad-K/V runs
the chunk axis in reverse via index maps (the paper's i = N..1 loops).
Grouped-query attention reads the KV block through an h // group index
map — no KV repetition is materialized.

Validated against kernels/ref.py and core/chunked.py in interpret mode
(this container is CPU-only; TPU is the lowering target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

from repro.core.numerics import safe_div
from repro.kernels.defaults import DEFAULT_TILES

F32 = jnp.float32
_CHUNK = DEFAULT_TILES["linear"]["chunk"]


def _pad_seq(x, n_pad):
    if x.shape[2] == n_pad:
        return x
    w = [(0, 0)] * x.ndim
    w[2] = (0, n_pad - x.shape[2])
    return jnp.pad(x, w)


def _causal_mask(rows: int, cols: int, row_mod: int | None = None):
    ii = lax.broadcasted_iota(jnp.int32, (rows, cols), 0)
    if row_mod is not None:
        ii = ii % row_mod
    jj = lax.broadcasted_iota(jnp.int32, (rows, cols), 1)
    return ii >= jj


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, g_ref, s_ref, p_ref, *,
                a: float, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        p_ref[...] = jnp.zeros_like(p_ref)

    q = q_ref[0, 0].astype(F32)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    c = q.shape[0]
    dv = v.shape[1]
    vaug = jnp.concatenate([v, jnp.ones((c, 1), F32)], axis=1)

    att = a + b * jnp.dot(q, k.T, preferred_element_type=F32)
    att = jnp.where(_causal_mask(c, c), att, 0.0)
    f = (jnp.dot(att, vaug, preferred_element_type=F32)
         + a * p_ref[...]
         + b * jnp.dot(q, s_ref[...], preferred_element_type=F32))
    g = f[:, dv]
    o_ref[0, 0] = (f[:, :dv] / g[:, None]).astype(o_ref.dtype)
    g_ref[0, 0] = g.astype(g_ref.dtype)

    s_ref[...] += jnp.dot(k.T, vaug, preferred_element_type=F32)
    p_ref[...] += jnp.sum(vaug, axis=0, keepdims=True)


def la_fwd_pallas(q, k, v, a: float, b: float, chunk: int = _CHUNK,
                  interpret: bool = False):
    """Returns (o, g).  q: (B,H,N,D); k,v: (B,Hkv,N,D)."""
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = h // hkv
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c
    q, k, v = (_pad_seq(x, n_pad) for x in (q, k, v))

    kernel = functools.partial(_fwd_kernel, a=a, b=b)
    o, g = pl.pallas_call(
        kernel,
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dv), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, n_pad, dv), q.dtype),
            jax.ShapeDtypeStruct((bsz, h, n_pad), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dk, dv + 1), F32),
            pltpu.VMEM((1, dv + 1), F32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o[:, :, :n], g[:, :, :n]


# ---------------------------------------------------------------------------
# Backward — grad Q (forward chunk scan; paper alpha^Q/beta^Q, Eq. 21)
# ---------------------------------------------------------------------------

def _bwd_q_kernel(k_ref, v_ref, om_ref, h_ref, dq_ref, a_ref, *, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)

    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    om = om_ref[0, 0].astype(F32)
    hv = h_ref[0, 0].astype(F32)
    c = k.shape[0]
    vaug = jnp.concatenate([v, jnp.ones((c, 1), F32)], axis=1)
    gmat = jnp.concatenate([om, -hv[:, None]], axis=1)  # [om_hat, -h]

    sc = jnp.dot(gmat, vaug.T, preferred_element_type=F32)
    sc = jnp.where(_causal_mask(c, c), sc, 0.0)
    dq = jnp.dot(sc, k, preferred_element_type=F32) + jnp.dot(
        gmat, a_ref[...].T, preferred_element_type=F32)
    dq_ref[0, 0] = (b * dq).astype(dq_ref.dtype)

    a_ref[...] += jnp.dot(k.T, vaug, preferred_element_type=F32)


# ---------------------------------------------------------------------------
# Backward — grad K / grad V (reverse chunk scan; alpha/beta^{K,V}, Eq. 21)
# ---------------------------------------------------------------------------

def _bwd_kv_kernel(q_ref, k_ref, v_ref, om_ref, h_ref, dk_ref, dv_ref,
                   u_ref, *, a: float, b: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        u_ref[...] = jnp.zeros_like(u_ref)

    g_, c, dk = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    dv = v_ref.shape[3]
    q = q_ref[0].astype(F32).reshape(g_ * c, dk)
    om = om_ref[0].astype(F32).reshape(g_ * c, dv)
    hv = h_ref[0].astype(F32).reshape(g_ * c, 1)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)

    vneg = jnp.concatenate([v, -jnp.ones((c, 1), F32)], axis=1)
    g2 = jnp.concatenate([om, hv], axis=1)                 # (G*C, D+1)
    u = u_ref[...]
    mask = _causal_mask(g_ * c, c, row_mod=c)              # i >= p per group

    sc = jnp.dot(g2, vneg.T, preferred_element_type=F32)
    sc = jnp.where(mask, sc, 0.0)
    dk_ = (jnp.dot(sc.T, q, preferred_element_type=F32)
           + jnp.dot(vneg, u[:dk, :].T, preferred_element_type=F32))
    dk_ref[0, 0] = (b * dk_).astype(dk_ref.dtype)

    att = a + b * jnp.dot(q, k.T, preferred_element_type=F32)
    att = jnp.where(mask, att, 0.0)
    dv_ = (jnp.dot(att.T, om, preferred_element_type=F32)
           + b * jnp.dot(k, u[:dk, :dv], preferred_element_type=F32)
           + a * u[dk, :dv][None, :])
    dv_ref[0, 0] = dv_.astype(dv_ref.dtype)

    qaug = jnp.concatenate([q, jnp.ones((g_ * c, 1), F32)], axis=1)
    u_ref[...] += jnp.dot(qaug.T, g2, preferred_element_type=F32)


def la_bwd_pallas(q, k, v, o, g, omega, a: float, b: float,
                  chunk: int = _CHUNK, interpret: bool = False):
    """Analytic backward from residuals {q,k,v,o,g}; returns (dq, dk, dv)."""
    bsz, h, n, dk = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    group = h // hkv
    c = min(chunk, n)
    n_pad = -(-n // c) * c
    t = n_pad // c

    om_hat = safe_div(omega.astype(F32), g[..., None])
    h_vec = jnp.sum(o.astype(F32) * om_hat, axis=-1)  # (B,H,N)
    q, k, v = (_pad_seq(x, n_pad) for x in (q, k, v))
    om_hat = _pad_seq(om_hat, n_pad)
    h_vec = _pad_seq(h_vec[..., None], n_pad)[..., 0]

    dq = pl.pallas_call(
        functools.partial(_bwd_q_kernel, b=b),
        grid=(bsz, h, t),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi // group, ti, 0)),
            pl.BlockSpec((1, 1, c, dv), lambda bi, hi, ti: (bi, hi, ti, 0)),
            pl.BlockSpec((1, 1, c), lambda bi, hi, ti: (bi, hi, ti)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, dk),
                               lambda bi, hi, ti: (bi, hi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, n_pad, dk), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv + 1), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(k, v, om_hat, h_vec)

    rev = lambda ti: t - 1 - ti  # noqa: E731 — reverse chunk iteration
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_kv_kernel, a=a, b=b),
        grid=(bsz, hkv, t),
        in_specs=[
            pl.BlockSpec((1, group, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, group, c, dv),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, group, c),
                         lambda bi, hi, ti: (bi, hi, rev(ti))),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dk),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
            pl.BlockSpec((1, 1, c, dv),
                         lambda bi, hi, ti: (bi, hi, rev(ti), 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, dk), k.dtype),
            jax.ShapeDtypeStruct((bsz, hkv, n_pad, dv), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((dk + 1, dv + 1), F32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, om_hat, h_vec)

    return dq[:, :, :n], dk[:, :, :n], dv[:, :, :n]
