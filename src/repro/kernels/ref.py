"""Pure-jnp quadratic oracles for every kernel in this package.

These materialize the full N x N attention matrix and are used only as
correctness references in tests and benchmarks.  All accumulation is f32.
"""
from __future__ import annotations

import jax.numpy as jnp


def expand_kv(x: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """Repeat KV heads (B, Hkv, N, D) -> (B, H, N, D) for grouped queries.

    Materializes the H/Hkv-fold copy — fine for the oracles here, and
    used (with a noted cost) by kernels that don't understand GQA yet.
    """
    b, hkv, n, d = x.shape
    if hkv == num_q_heads:
        return x
    assert num_q_heads % hkv == 0, (num_q_heads, hkv)
    g = num_q_heads // hkv
    return jnp.repeat(x, g, axis=1)


_expand_kv = expand_kv  # backwards-compatible private alias


def la_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    a: float = 1.0,
    b: float = 1.0,
    causal: bool = True,
) -> jnp.ndarray:
    """Normalized linear attention, paper Eq. 4.

    o_ij = sum_n (a + b q_i.k_n) v_nj / sum_n (a + b q_i.k_n)

    q: (B, H, Nq, D); k, v: (B, Hkv, Nk, D) with Hkv | H.
    Returns (B, H, Nq, D) in q.dtype.  O(N^2 D) time, O(N^2) memory —
    reference only.
    """
    out_dtype = q.dtype
    h = q.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    s = jnp.einsum("bhid,bhjd->bhij", qf, kf)
    w = a + b * s
    if causal:
        nq, nk = w.shape[-2], w.shape[-1]
        mask = jnp.tril(jnp.ones((nq, nk), dtype=bool), k=nk - nq)
        w = jnp.where(mask, w, 0.0)
    g = w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhij,bhjd->bhid", w, vf) / g
    return o.astype(out_dtype)


def softmax_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Regular softmax attention oracle (paper Eq. 2/3)."""
    out_dtype = q.dtype
    h, d = q.shape[1], q.shape[-1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    scale = (1.0 / d**0.5) if scale is None else scale
    s = jnp.einsum("bhid,bhjd->bhij", qf, kf) * scale
    if causal:
        nq, nk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((nq, nk), dtype=bool), k=nk - nq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhij,bhjd->bhid", p, vf)
    return o.astype(out_dtype)


def ssd_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_decay: jnp.ndarray,
) -> jnp.ndarray:
    """State-space-duality (Mamba-2) oracle: scalar-decay linear attention.

    Recurrence (paper Appendix B, Table 3, Mamba-2 row):
        S_t = gamma_t S_{t-1} + k_t v_t^T,   o_t = q_t S_t
    with gamma_t = exp(log_decay_t) in (0, 1].

    q, k: (B, H, N, Dk); v: (B, H, N, Dv); log_decay: (B, H, N) <= 0.
    Materializes M_in = prod_{m=n+1..i} gamma_m via cumulative log sums.
    """
    out_dtype = v.dtype
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    ld = log_decay.astype(jnp.float32)
    cl = jnp.cumsum(ld, axis=-1)  # (B,H,N) cumulative log decay
    # M[i, n] = exp(cl_i - cl_n) for n <= i else 0
    diff = cl[..., :, None] - cl[..., None, :]
    n = diff.shape[-1]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    m = jnp.where(mask, jnp.exp(diff), 0.0)
    s = jnp.einsum("bhid,bhjd->bhij", qf, kf) * m
    o = jnp.einsum("bhij,bhjd->bhid", s, vf)
    return o.astype(out_dtype)
