"""Pure-jnp quadratic oracles for every kernel in this package.

These materialize the full N x N attention matrix and are used only as
correctness references in tests and benchmarks.  All accumulation is f32.

The oracles are GROUPED-native: queries are viewed as (B, Hkv, G, N, D)
and contracted against the unexpanded (B, Hkv, N, D) keys/values, so
parity tests compare kernels against an oracle that — like the kernels —
never materializes an H/Hkv-fold KV copy.
"""
from __future__ import annotations

import jax.numpy as jnp


def expand_kv(x: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """Repeat KV heads (B, Hkv, N, D) -> (B, H, N, D) for grouped queries.

    Materializes the H/Hkv-fold copy — kept only for tests that want the
    expanded layout explicitly; the oracles below no longer use it.
    """
    b, hkv, n, d = x.shape
    if hkv == num_q_heads:
        return x
    assert num_q_heads % hkv == 0, (num_q_heads, hkv)
    g = num_q_heads // hkv
    return jnp.repeat(x, g, axis=1)


_expand_kv = expand_kv  # backwards-compatible private alias


def la_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    a: float = 1.0,
    b: float = 1.0,
    causal: bool = True,
) -> jnp.ndarray:
    """Normalized linear attention, paper Eq. 4.

    o_ij = sum_n (a + b q_i.k_n) v_nj / sum_n (a + b q_i.k_n)

    q: (B, H, Nq, D); k, v: (B, Hkv, Nk, D) with Hkv | H.
    Returns (B, H, Nq, D) in q.dtype.  O(N^2 D) time, O(N^2) memory —
    reference only.
    """
    out_dtype = q.dtype
    bq, h, nq, d = q.shape
    hkv, nk = k.shape[1], k.shape[2]
    qg = q.reshape(bq, hkv, h // hkv, nq, d).astype(jnp.float32)
    kf, vf = (x.astype(jnp.float32) for x in (k, v))
    s = jnp.einsum("bkgid,bkjd->bkgij", qg, kf)
    w = a + b * s
    if causal:
        mask = jnp.tril(jnp.ones((nq, nk), dtype=bool), k=nk - nq)
        w = jnp.where(mask, w, 0.0)
    g = w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgij,bkjd->bkgid", w, vf) / g
    return o.reshape(bq, h, nq, vf.shape[-1]).astype(out_dtype)


def softmax_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Regular softmax attention oracle (paper Eq. 2/3)."""
    out_dtype = q.dtype
    bq, h, nq, d = q.shape
    hkv, nk = k.shape[1], k.shape[2]
    qg = q.reshape(bq, hkv, h // hkv, nq, d).astype(jnp.float32)
    kf, vf = (x.astype(jnp.float32) for x in (k, v))
    scale = (1.0 / d**0.5) if scale is None else scale
    s = jnp.einsum("bkgid,bkjd->bkgij", qg, kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((nq, nk), dtype=bool), k=nk - nq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgij,bkjd->bkgid", p, vf)
    return o.reshape(bq, h, nq, vf.shape[-1]).astype(out_dtype)


def gla_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_decay: jnp.ndarray,
    a: float = 1.0,
    b: float = 1.0,
    return_g: bool = False,
):
    """Decay-gated normalized linear attention oracle (GLA family).

    o_i = sum_{n<=i} M_in (a + b q_i.k_n) v_n / sum_{n<=i} M_in (a + b
    q_i.k_n), with M_in = prod_{m=n+1..i} gamma_m and gamma = exp(ld).

    q: (B, H, N, D); k, v: (B, Hkv, N, D) with Hkv | H; log_decay:
    (B, Hkv, N) <= 0 — the decayed state is per KV head, shared across
    the query group, so the decay mask is built once per KV head.
    log_decay == 0 reduces EXACTLY to `la_ref`.  O(N^2) — tests only.
    return_g=True also returns the (B, H, N) f32 normalizer (the ref
    KernelImpl's residual — computed here so the impl cannot drift
    from the oracle's masking convention).
    """
    out_dtype = q.dtype
    bq, h, n, d = q.shape
    hkv = k.shape[1]
    qg = q.reshape(bq, hkv, h // hkv, n, d).astype(jnp.float32)
    kf, vf = (x.astype(jnp.float32) for x in (k, v))
    cl = jnp.cumsum(log_decay.astype(jnp.float32), axis=-1)  # (B,Hkv,N)
    diff = cl[..., :, None] - cl[..., None, :]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    # double-where: masked exponents are large POSITIVE differences that
    # overflow and would poison autodiff of this oracle with nan grads
    m = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    s = a + b * jnp.einsum("bkgid,bkjd->bkgij", qg, kf)
    w = s * m[:, :, None]
    g = w.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgij,bkjd->bkgid", w, vf) / g
    o = o.reshape(bq, h, n, vf.shape[-1]).astype(out_dtype)
    if return_g:
        return o, g[..., 0].reshape(bq, h, n)
    return o


def ssd_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    log_decay: jnp.ndarray,
) -> jnp.ndarray:
    """State-space-duality (Mamba-2) oracle: scalar-decay linear attention.

    Recurrence (paper Appendix B, Table 3, Mamba-2 row):
        S_t = gamma_t S_{t-1} + k_t v_t^T,   o_t = q_t S_t
    with gamma_t = exp(log_decay_t) in (0, 1].

    q, k: (B, G, N, Dk) with G | H (shared grouped heads, NOT expanded);
    v: (B, H, N, Dv); log_decay: (B, H, N) <= 0.  The per-head decay
    matrix M[i, n] = prod_{m=n+1..i} gamma_m comes from cumulative log
    sums over a (B, G, H/G, ...) view, the shared q/k scores from one
    grouped einsum.
    """
    out_dtype = v.dtype
    b, grp, n, _ = q.shape
    h = v.shape[1]
    g = h // grp
    qf, kf = (x.astype(jnp.float32) for x in (q, k))
    vf = v.astype(jnp.float32).reshape(b, grp, g, n, v.shape[-1])
    ld = log_decay.astype(jnp.float32).reshape(b, grp, g, n)
    cl = jnp.cumsum(ld, axis=-1)  # (B,G,g,N) cumulative log decay
    # M[i, n] = exp(cl_i - cl_n) for n <= i else 0
    diff = cl[..., :, None] - cl[..., None, :]
    mask = jnp.tril(jnp.ones((n, n), dtype=bool))
    m = jnp.where(mask, jnp.exp(diff), 0.0)
    s = jnp.einsum("bkid,bkjd->bkij", qf, kf)  # shared across the group
    o = jnp.einsum("bkij,bkgij,bkgjd->bkgid", s, m, vf)
    return o.reshape(b, h, n, v.shape[-1]).astype(out_dtype)
