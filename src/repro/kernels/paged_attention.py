"""Pallas TPU paged-attention DECODE kernel (vLLM-style paged KV).

The serving engine's paged softmax path (docs/paged_kv.md) keeps each
layer's KV in a shared arena of fixed-size pages and addresses it with
per-slot page tables; this module is the kernel that reads that layout.
It finally runs softmax DECODE through a kernel instead of the per-slot
einsum that mixers/softmax.py carried since the seed (ROADMAP item).

`paged_attention_pallas` — one query token per slot against its paged
context:

  * grid (B, H, Pmax) with the page walk as the sequential axis; the
    page table and per-slot lengths ride in via scalar prefetch
    (PrefetchScalarGridSpec), so the KV BlockSpec index map resolves
    `page_table[b, i]` BEFORE the block DMA is issued — the kernel
    gathers K/V pages straight from the arena, no host-side gather;
  * GQA-native: the arena BlockSpecs index by `head // group`, grouped
    query heads stream the same page once (the arena is (P, Hkv, ps, d),
    never expanded to H);
  * per-slot lengths: page-walk iterations past a slot's last allocated
    page are clamped to it in the index map (the pipeline re-fetches
    nothing) and their compute is skipped, so each slot pays for ITS
    context, not the deepest one; in-page tail keys mask by length;
  * logsumexp-stable: online softmax with a running max/sum in VMEM
    scratch, f32 accumulation, and a guarded finalize divide so a slot
    with length 0 (empty / retired) yields zeros, never NaN.

`paged_attention_xla` is the gather-then-softmax oracle (also the CPU
serving impl); both register as the "paged" KernelImpl family in
kernels/ops.py, mirroring linear/softmax/ssd.  Decode is inference-only,
so the family has no backward.

Validated in interpret mode against the oracle and against the
contiguous-cache decode (tests/test_paging.py); TPU is the lowering
target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

from repro.kernels.defaults import DEFAULT_TILES

F32 = jnp.float32
NEG_INF = -1e30
_PPB = DEFAULT_TILES["paged"]["pages_per_block"]


# ---------------------------------------------------------------------------
# XLA oracle / CPU impl: gather the pages, then masked softmax
# ---------------------------------------------------------------------------

def gather_pages(pages, page_table):
    """(P, Hkv, ps, d) arena + (B, Pmax) table -> contiguous (B, Hkv, S, d)
    with S = Pmax * ps.  Entries past a slot's allocation gather the
    engine's sink page — callers mask by length before reading them."""
    b, pmax = page_table.shape
    _, hkv, ps, d = pages.shape
    gat = pages[page_table]                    # (B, Pmax, Hkv, ps, d)
    return gat.transpose(0, 2, 1, 3, 4).reshape(b, hkv, pmax * ps, d)


def paged_attention_xla(q, k_pages, v_pages, page_table, lengths):
    """Reference paged decode: q (B, H, 1, d) over paged KV.

    k_pages / v_pages: (P, Hkv, ps, d) shared arenas; page_table:
    (B, Pmax) int32; lengths: (B,) int32 — slot b attends to its first
    lengths[b] tokens (the just-written one included).  Returns
    (B, H, 1, d) in q.dtype.

    Paged == gather + contiguous, BY CONSTRUCTION: this runs the
    registered "softmax_decode" xla impl on the gathered layout (one
    masked-softmax decode to maintain, not two) and adds only the
    guarded zeroing of fully-masked length-0 slots — parity with the
    pallas kernel's guarded finalize.
    """
    from repro.kernels import ops as _ops
    o = _ops.softmax_decode(q, gather_pages(k_pages, page_table),
                            gather_pages(v_pages, page_table), lengths,
                            backend="xla")
    return jnp.where((lengths > 0)[:, None, None, None], o, 0.0)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _decode_kernel(pt_ref, len_ref, q_ref, *refs, scale: float,
                   nblk: int, ppb: int):
    # refs = [k_0, v_0, ..., k_{ppb-1}, v_{ppb-1}, o, acc, m, l]: the
    # pages_per_block tunable (repro.tune) widens a sequential grid step
    # to ppb page DMAs, amortizing per-step grid overhead; ppb == 1 is
    # byte-identical to the original one-page-per-step kernel.
    kv_refs, o_ref = refs[:2 * ppb], refs[2 * ppb]
    acc_ref, m_ref, l_ref = refs[2 * ppb + 1:]
    bi = pl.program_id(0)
    blk = pl.program_id(2)
    length = len_ref[bi]
    ps = kv_refs[0].shape[2]

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    for j in range(ppb):
        pi = blk * ppb + j
        k_ref, v_ref = kv_refs[2 * j], kv_refs[2 * j + 1]

        # pages at or past the slot's frontier were clamped in the index
        # map (no DMA) and contribute nothing — skip their compute
        @pl.when(pi * ps < length)
        def _step(k_ref=k_ref, v_ref=v_ref, pi=pi):
            q = q_ref[0, 0].astype(F32)            # (1, d)
            k = k_ref[0, 0].astype(F32)            # (ps, d)
            v = v_ref[0, 0].astype(F32)
            s = scale * jnp.dot(q, k.T,
                                preferred_element_type=F32)  # (1, ps)
            jj = pi * ps + lax.broadcasted_iota(jnp.int32, (1, ps), 1)
            s = jnp.where(jj < length, s, NEG_INF)
            m_prev = m_ref[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            corr = jnp.exp(m_prev - m_new)
            p = jnp.exp(s - m_new)
            l_ref[...] = corr * l_ref[...] + p.sum(axis=1, keepdims=True)
            acc_ref[...] = corr * acc_ref[...] + jnp.dot(
                p, v, preferred_element_type=F32)
            m_ref[...] = m_new

    @pl.when(blk == nblk - 1)
    def _finalize():
        # a length-0 slot accumulates l == 0; guard the divide so the
        # retired slots of a serving batch finalize to zeros, not NaN
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, page_table, lengths,
                           scale: float | None = None,
                           pages_per_block: int = _PPB,
                           interpret: bool = False):
    """Paged-KV decode through Pallas; same contract as the xla oracle.

    q: (B, H, 1, d); k_pages/v_pages: (P, Hkv, ps, d); page_table:
    (B, Pmax) int32 arena-page ids; lengths: (B,) int32 per-slot context
    lengths.  Every page id must be a valid arena index (the engine's
    sink page backs unallocated table entries).

    pages_per_block (the family's tunable tile, repro.tune): KV pages
    streamed + processed per sequential grid step.  Arena pages are not
    contiguous, so a wider block cannot be one BlockSpec; instead each
    of the ppb pages rides in as its own scalar-prefetched input ref and
    the kernel walks them within the step.  Output is invariant in it.
    """
    b, h, nq, d = q.shape
    assert nq == 1, f"paged_attention is a decode kernel (nq={nq})"
    hkv, ps = k_pages.shape[1], k_pages.shape[2]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    pmax = page_table.shape[1]
    ppb = max(1, min(pages_per_block, pmax))
    nblk = -(-pmax // ppb)
    scale = (1.0 / d ** 0.5) if scale is None else scale

    def kv_index_for(j):
        def kv_index(bi, hi, blk, pt, lens):
            # clamp the walk at the slot's last allocated page:
            # iterations past it keep the same block index, so no new
            # DMA is issued (also bounds the pmax % ppb tail reads)
            frontier = jnp.maximum(lens[bi] - 1, 0) // ps
            pi = jnp.minimum(blk * ppb + j, frontier)
            return (pt[bi, pi], hi // group, 0, 0)
        return kv_index

    kv_specs = []
    for j in range(ppb):
        kv_specs += [pl.BlockSpec((1, 1, ps, d), kv_index_for(j)),
                     pl.BlockSpec((1, 1, ps, d), kv_index_for(j))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda bi, hi, blk, pt, lens: (bi, hi, 0, 0)),
            *kv_specs,
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda bi, hi, blk, pt, lens: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), F32),
            pltpu.VMEM((1, 1), F32),
            pltpu.VMEM((1, 1), F32),
        ],
    )
    kv_args = []
    for _ in range(ppb):
        kv_args += [k_pages, v_pages]
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, nblk=nblk, ppb=ppb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, *kv_args)
