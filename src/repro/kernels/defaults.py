"""Default kernel tile sizes — the autotuner's fallback source of truth.

Every Pallas kernel module in this package used to carry its own copy of
the hand-picked block/chunk constants (128 tokens per chunk for the
chunked-recurrence families, 128x128 q/k blocks for flash, one page per
grid step for paged decode).  They live HERE now, in one table, for two
reasons:

  * `repro.tune` — the autotuning subsystem — needs a deterministic
    fallback when the tuning cache has no entry for a (family, impl,
    op, shape-bucket, dtype, device) key.  That fallback must be
    byte-identical to the pre-autotuner behavior, so there must be
    exactly one copy of it.
  * the search spaces in `repro.tune.space` are defined AROUND these
    values; keeping both in sight makes a sweep's "did it beat the
    default" question answerable without grepping five kernel files.

The table maps kernel family -> {tile parameter: default value}.  The
parameter names are exactly the keyword arguments of the corresponding
kernel entry points (`la_fwd_pallas(chunk=...)`,
`flash_attention_pallas(block_q=..., block_k=...)`,
`paged_attention_pallas(pages_per_block=...)`), and exactly the keys a
tuning-cache entry may override at dispatch time (kernels/ops.py).

`DEFAULT_SCAN_CHUNK` (512, re-exported as `ops.DEFAULT_CHUNK`) is the
one value that is NOT a kernel tile: it is the CALLER-level scan
granularity default recorded in `configs.base.LACfg` — how much work
each chunked-scan iteration covers — while the table entries are the
KERNEL-level tile defaults used when a Pallas entry point is called
without an explicit size.  It lives here with them because this module
is the single home for size literals (repro.check lint REPRO-L002).
"""
from __future__ import annotations

# caller-level scan chunk (configs.base.LACfg.chunk mirrors it):
# 512 tokens/chunk costs +3% intra-chunk flops vs 128 but 4x fewer scan
# iterations -> -20% HBM traffic on train cells (EXPERIMENTS §Perf)
DEFAULT_SCAN_CHUNK = 512

DEFAULT_TILES: dict[str, dict[str, int]] = {
    # chunked-recurrence families: tokens per sequential grid step
    "linear": {"chunk": 128},
    "gla": {"chunk": 128},
    "ssd": {"chunk": 128},
    # flash (softmax pallas): query/key block edge lengths
    "softmax": {"block_q": 128, "block_k": 128},
    # paged decode: KV pages fetched + processed per sequential grid step
    "paged": {"pages_per_block": 1},
    # fused decode epilogues (kernels/decode_fused.py): the contiguous
    # softmax variant streams the cache in block_k-key blocks, the paged
    # variant reuses the pages_per_block walk; the linear/gla fused
    # steps are one grid cell per (slot, kv head) and have no tile
    "softmax_decode_fused": {"block_k": 128},
    "paged_decode_fused": {"pages_per_block": 1},
}


def default_tiles(family: str) -> dict[str, int]:
    """A fresh copy of the family's default tile parameters.

    Raises KeyError with the known families listed — the same contract
    as the KernelImpl registry's unknown-name error.
    """
    try:
        return dict(DEFAULT_TILES[family])
    except KeyError:
        raise KeyError(
            f"no default tiles for kernel family {family!r}; known "
            f"families: {sorted(DEFAULT_TILES)}") from None
