"""LR schedules — cosine warmup/decay per the paper's §5.2 recipe
(min 5e-5, max 1e-3, cosine warmup and decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup_decay(step, *, max_lr: float, min_lr: float,
                        warmup_steps: int, total_steps: int):
    """Linear warmup to max_lr, cosine decay to min_lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.maximum(warmup_steps, 1)
    warm_lr = max_lr * step / warm
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos_lr = min_lr + 0.5 * (max_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm_lr, cos_lr)


def constant(step, *, lr: float):
    return jnp.full((), lr, jnp.float32)
