"""AdamW with decoupled weight decay and global-norm clipping.

Functional, pytree-based; moments are stored in f32 regardless of param
dtype.  Under pjit the moments inherit the param's PartitionSpec
(ZeRO-style sharding — see distributed/zero.py for the explicit rules).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object       # pytree like params, f32
    nu: object       # pytree like params, f32


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, F32)  # noqa: E731
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale), grads), norm


def apply(params, grads, state: AdamWState, *, lr, beta1=0.9, beta2=0.95,
          eps=1e-8, weight_decay=0.1, grad_clip=0.0):
    """Returns (new_params, new_state, metrics)."""
    if grad_clip:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        grads = jax.tree.map(lambda g: g.astype(F32), grads)
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1 - beta1 ** step.astype(F32)
    b2c = 1 - beta2 ** step.astype(F32)

    def upd(p, g, mu, nu):
        mu = beta1 * mu + (1 - beta1) * g
        nu = beta2 * nu + (1 - beta2) * g * g
        mu_hat = mu / b1c
        nu_hat = nu / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + eps)
        # decoupled weight decay: only >=2D weights (skip norms/biases)
        if p.ndim >= 2 and weight_decay:
            delta = delta + weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
