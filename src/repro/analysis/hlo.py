"""Structural cost analysis of compiled (post-SPMD) HLO text.

Why not compiled.cost_analysis()?  XLA's HloCostAnalysis counts each
while-loop BODY ONCE, so with scan-over-layers + chunked-scan kernels +
chunked CE the reported flops/bytes undercount by the trip counts
(verified empirically: a 36-layer scanned model reports ~2 layers of
flops).  The compiled text, however, carries
`backend_config={"known_trip_count":{"n":...}}` on every while op, so an
exact structural walk is possible:

  total(comp) = local(comp) + sum_{while in comp} trip * total(body)
                            + sum_{call in comp}  total(callee)

Local costs per computation:
  * flops            — dot ops: 2 * output_elems * contraction_size
                       (also recursed into fusions: dots dominate >>99%)
  * bytes accessed   — per top-level instruction: operand + output bytes
                       (fusions count at their boundary = true HBM
                       traffic; bookkeeping ops skipped)
  * collective bytes — operand bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute

All shapes in the compiled module are per-device shard shapes, so every
number is PER CHIP.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "iota", "partition-id",
    "replica-id",
}

_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)$")
# the output type may be a tuple containing `/*index=5*/` comments (=, /)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[\w\[\],\{\}\s\/\*=]+?\)?)\s+"
    r"([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\(?[\w\[\],\{\}\s\/\*]+?\)?)(?:,|\)\s*->|$)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w\.\-]+)")


def _shapes_of(type_str):
    """All (dtype, dims) in a type string (tuples yield several)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _type_bytes(type_str) -> int:
    total = 0
    for dt, shape in _shapes_of(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    # (multiplier, callee, kind): kind in {"while", "call", "fusion"}
    calls: list = field(default_factory=list)
    dot_sites: list = field(default_factory=list)
    byte_sites: list = field(default_factory=list)


def _io_bytes(op, out_bytes, opnd_sizes):
    """HBM-traffic model for one instruction.

    Alias-aware: XLA buffer assignment updates loop-carried buffers in
    place, so a dynamic-update-slice (or a fusion ending in one) whose
    output matches an operand's size does NOT rewrite the whole buffer —
    traffic is just the updated slice.  Similarly dynamic-slice reads
    only the slice, and gathers read ~the output, not the whole table.
    """
    if op == "dynamic-slice":
        return 2 * out_bytes                      # read slice, write out
    if op == "gather":
        return 2 * out_bytes
    if op == "dynamic-update-slice":
        slice_b = min(opnd_sizes) if opnd_sizes else 0
        slice_b = min((b for b in opnd_sizes if 0 < b < out_bytes),
                      default=slice_b)
        return 2 * slice_b                        # read + write the slice
    if op == "fusion" and out_bytes in opnd_sizes:
        # fusion whose output size equals an operand's: XLA aliases the
        # buffer in place (scan-carry update); traffic = other inputs r+w
        others = sum(opnd_sizes) - out_bytes
        return 2 * max(others, 0)
    return out_bytes + sum(opnd_sizes)


def _parse_instruction(line, symtab, comp: _Comp):
    m = _DEF_RE.match(line)
    if not m:
        return
    name, type_str, op, rest = m.groups()
    symtab[name] = type_str
    out_bytes = _type_bytes(type_str)
    operands_str = rest.split(")")[0]
    opnds = re.findall(r"%([\w\.\-]+)", operands_str)
    opnd_sizes = [_type_bytes(symtab.get(o, "")) for o in opnds]
    opnd_bytes = sum(opnd_sizes)

    if op not in _SKIP_BYTES_OPS and not op.startswith("fusion"):
        b = _io_bytes(op, out_bytes, opnd_sizes)
        comp.bytes_accessed += b
        if b > 1 << 20:
            comp.byte_sites.append((b, op, type_str.strip()[:48],
                                    line.strip()[:140]))
    if op == "fusion":
        b = _io_bytes(op, out_bytes, opnd_sizes)
        comp.bytes_accessed += b
        if b > 1 << 20:
            comp.byte_sites.append((b, op, type_str.strip()[:48],
                                    line.strip()[:140]))
        cm = _CALLEE_RE.search(rest)
        if cm:
            comp.calls.append((1, cm.group(1), "fusion"))
    elif op == "while":
        tm = _TRIP_RE.search(line)
        trip = int(tm.group(1)) if tm else 1
        cm = re.search(r"body=%?([\w\.\-]+)", rest)
        if cm:
            comp.calls.append((trip, cm.group(1), "while"))
    elif op in ("call", "custom-call") or op.endswith("-start"):
        cm = _CALLEE_RE.search(rest)
        if cm:
            comp.calls.append((1, cm.group(1), "call"))
    elif op == "conditional":
        for cm in re.finditer(r"branch_computations=\{([^}]*)\}", rest):
            for callee in re.findall(r"%?([\w\.\-]+)", cm.group(1)):
                comp.calls.append((1, callee, "call"))

    base = op.removesuffix("-start")
    if base in COLLECTIVES and not op.endswith("-done"):
        nb = opnd_bytes or out_bytes
        comp.coll_bytes += nb
        comp.coll_by_kind[base] += nb

    if op == "dot":
        # contraction size from lhs shape x lhs_contracting_dims
        lhs_type = symtab.get(opnds[0], "") if opnds else ""
        lhs_shapes = _shapes_of(lhs_type)
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        contract = 1
        if lhs_shapes and cdims and cdims.group(1):
            shape = lhs_shapes[0][1]
            for d in cdims.group(1).split(","):
                di = int(d)
                if di < len(shape):
                    contract *= shape[di]
        out_elems = 1
        for _, shape in _shapes_of(type_str):
            for d in shape:
                out_elems *= d
        fl = 2.0 * out_elems * contract
        comp.flops += fl
        comp.dot_sites.append((fl, type_str.strip(), line.strip()[:140]))


def parse_module(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    symtab: dict[str, str] = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and "{" in line and "=" not in line.split("(")[0]:
                cur = _Comp(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                symtab = {}
                for pm in _PARAM_RE.finditer(m.group(2)):
                    symtab[pm.group(1)] = pm.group(2)
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        _parse_instruction(line, symtab, cur)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def total_costs(text: str) -> dict:
    """Walk from ENTRY multiplying while bodies by known trip counts."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "by_kind": {}}
    memo: dict[str, tuple] = {}

    def walk(name: str, flops_only: bool = False):
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0, {})
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0, {})  # cycle guard
        fl, by, cb = c.flops, c.bytes_accessed, c.coll_bytes
        kinds = dict(c.coll_by_kind)
        if flops_only:
            by, cb, kinds = 0.0, 0.0, {}
        for mult, callee, kind in c.calls:
            cf, cby, ccb, ck = walk(callee, flops_only
                                    or kind == "fusion")
            fl += mult * cf
            by += mult * cby
            cb += mult * ccb
            for k, v in ck.items():
                kinds[k] = kinds.get(k, 0.0) + mult * v
        memo[key] = (fl, by, cb, kinds)
        return memo[key]

    fl, by, cb, kinds = walk(entry.name)
    return {"flops": fl, "bytes": by, "collective_bytes": cb,
            "by_kind": kinds}


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-corrected per-chip collective bytes (see total_costs)."""
    t = total_costs(hlo_text)
    return {"total": t["collective_bytes"], "by_kind": t["by_kind"],
            "ops": []}


def top_dot_sites(text: str, k: int = 10) -> list:
    """Largest matmuls weighted by trip-count multiplier (perf work)."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    mults: dict[str, float] = defaultdict(float)

    def walk(name, mult):
        c = comps.get(name)
        if c is None or mult <= 0 or mults[name] >= mult:
            return
        mults[name] = max(mults[name], mult)
        for m, callee, _ in c.calls:
            walk(callee, mult * m)

    walk(entry.name, 1.0)
    sites = []
    for name, mult in mults.items():
        for fl, ty, line in comps[name].dot_sites:
            sites.append((fl * mult, mult, ty, line))
    sites.sort(key=lambda s: -s[0])
    return sites[:k]


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# ---------------------------------------------------------------------------
# Buffer-donation aliasing (serving decode step)
# ---------------------------------------------------------------------------

# one aliasing entry: {output_index}: (param_number, {param_index}, kind)
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\},\s*([\w-]+)\)")


def _index_tuple(s: str) -> tuple:
    return tuple(int(x) for x in s.split(",") if x.strip())


def input_output_aliases(hlo_text: str) -> list[dict]:
    """ENTRY input->output aliasing pairs of a compiled module.

    Parses the `input_output_alias={ {1}: (0, {}, may-alias), ... }`
    header XLA emits when inputs are donated (jit donate_argnums) and
    buffer assignment accepted the donation.  Returns one dict per pair:
    {"output_index": tuple, "param_number": int, "param_index": tuple,
    "kind": str}.  Empty list: nothing aliased — every donated buffer
    was silently copied.
    """
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    i = hlo_text.index("{", start)
    depth, j = 0, i
    while j < len(hlo_text):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    body = hlo_text[i + 1:j]
    return [{"output_index": _index_tuple(m.group(1)),
             "param_number": int(m.group(2)),
             "param_index": _index_tuple(m.group(3)),
             "kind": m.group(4)}
            for m in _ALIAS_ENTRY_RE.finditer(body)]


def assert_cache_donation(compiled, min_leaves: int = 1) -> list[dict]:
    """Assert a compiled step aliases >= min_leaves inputs to outputs.

    The serving engine donates the decode cache (jit donate_argnums) so
    XLA updates the KV / state arenas in place instead of copying them
    every token; this is the pin that the donation actually survived
    compilation.  Accepts a jax `Compiled` object or HLO text; returns
    the parsed alias entries.
    """
    text = compiled if isinstance(compiled, str) else compiled.as_text()
    aliases = input_output_aliases(text)
    if len(aliases) < min_leaves:
        raise AssertionError(
            f"expected >= {min_leaves} input->output aliasing pairs "
            f"(donated decode cache) in the compiled module, found "
            f"{len(aliases)}: {aliases}")
    return aliases


def top_bytes_sites(text: str, k: int = 15) -> list:
    """Largest HBM-traffic instructions weighted by loop multipliers,
    using the same alias-aware model as total_costs (perf-work tool)."""
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    mults: dict[str, float] = defaultdict(float)

    def walk(name, mult):
        c = comps.get(name)
        if c is None or mults[name] >= mult:
            return
        mults[name] = max(mults[name], mult)
        for m, callee, kind in c.calls:
            if kind != "fusion":  # fusion internals don't touch HBM
                walk(callee, mult * m)

    walk(entry.name, 1.0)
    sites = []
    for name, mult in mults.items():
        for b, op, ty, line in comps[name].byte_sites:
            sites.append((b * mult, mult, op, ty, line))
    sites.sort(key=lambda s: -s[0])
    return sites[:k]
