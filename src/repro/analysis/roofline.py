"""Three-term roofline model from the compiled dry-run artifact.

TPU v5e constants (target hardware; this container is CPU-only):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

compiled.cost_analysis() is PER-DEVICE (the post-SPMD module), so:
    T_compute    = flops_per_device / peak
    T_memory     = bytes_per_device / hbm_bw
    T_collective = collective_bytes_per_device / link_bw
which equals the global formulation HLO_FLOPs / (chips * peak) etc.

MODEL_FLOPS = 6 * N_params * D_tokens (dense; active params for MoE) is
the "useful work" yardstick; usefulness = MODEL_FLOPS / (global HLO
FLOPs) exposes remat/dispatch overhead.  Caveat recorded per cell: the
collective term uses raw payload bytes (ring-algorithm factors ~2x for
all-reduce are noted, not applied).
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

# per-device-kind (jax.default_backend()) peaks for the KERNEL-level
# roofline below.  "cpu" is a ballpark host figure (a few AVX cores +
# dual-channel DRAM) — on CPU the absolute fraction is not a claim, but
# the denominator keeps BENCH_*.json cells structurally identical
# across devices so CI can assert on their presence everywhere.
DEVICE_SPECS = {
    "tpu": {"peak_flops": PEAK_FLOPS, "mem_bw": HBM_BW},
    "gpu": {"peak_flops": 165e12, "mem_bw": 768e9},   # A6000 (paper hw)
    "cpu": {"peak_flops": 100e9, "mem_bw": 20e9},
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops: float            # 6*N*D global
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    usefulness: float = 0.0
    memory_stats: dict | None = None
    collective_detail: dict | None = None
    note: str = ""

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops_per_device / PEAK_FLOPS
        self.t_memory = self.bytes_per_device / HBM_BW
        self.t_collective = self.collective_bytes / LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        global_flops = self.flops_per_device * self.chips
        self.usefulness = (self.model_flops / global_flops
                           if global_flops else 0.0)
        return self

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term is to pure compute-bound."""
        return self.t_compute / self.bound_time if self.bound_time else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["bound_time_s"] = self.bound_time
        d["roofline_fraction"] = self.roofline_fraction
        return d


def attention_costs(family: str, shape: dict, op: str = "fwd",
                    itemsize: int = 4) -> dict:
    """Structural flops/bytes of one attention kernel call.

    `shape` uses the dispatch-layer keys (kernels/ops.py): b, h, hkv, n,
    d (+ page_size for paged).  Bytes are the IDEAL streaming traffic —
    each operand crosses HBM once (what the Pallas kernels achieve by
    construction); flops count multiply-adds as 2.  `op` scales for the
    backward: fwdbwd ≈ 3.5x fwd for the recomputation-based backwards
    (2 extra matmuls per forward matmul, plus the recompute), the
    conventional flash accounting.
    """
    b, h, n, d = shape["b"], shape["h"], shape["n"], shape["d"]
    hkv = shape.get("hkv", h)
    if family in ("linear", "gla", "ssd"):
        # chunked scan: intra-chunk scores+weighting ~ O(n c d) and
        # state update/query ~ O(n d^2); c is a tile choice, so charge
        # the tile-independent O(n d^2) term (the d^2 state is the
        # family's defining cost, paper Sec. 4)
        flops = 2.0 * b * h * n * (2 * d * d)
        nbytes = itemsize * (2.0 * b * h * n * d          # q, o
                             + 2.0 * b * hkv * n * d)     # k, v
        if family in ("gla", "ssd"):
            nbytes += itemsize * b * hkv * n              # log-decay
    elif family in ("softmax", "softmax_decode"):
        causal_frac = 0.5 if family == "softmax" else 1.0
        flops = 2.0 * 2.0 * b * h * n * n * d * causal_frac  # qk^T + pv
        if family == "softmax_decode":
            flops = 2.0 * 2.0 * b * h * n * d             # one query row
        nbytes = itemsize * (2.0 * b * h * (n if family == "softmax"
                                            else 1) * d   # q, o
                             + 2.0 * b * hkv * n * d)     # k, v
    elif family == "paged":
        # one-token decode: n here is pmax * page_size (the padded
        # context); every mapped page is read once
        flops = 2.0 * 2.0 * b * h * n * d
        nbytes = itemsize * (2.0 * b * h * d              # q, o rows
                             + 2.0 * b * hkv * n * d)     # K/V pages
    elif family in ("linear_decode_fused", "gla_decode_fused"):
        # one-token fused recurrent step: the f32 state page crosses HBM
        # exactly once each way (read + in-place write); the k^T v_aug
        # rank-1 update and the grouped q·S readout are the only matmuls
        flops = 2.0 * b * hkv * d * (d + 1) \
            + 2.0 * b * h * d * (d + 1)
        nbytes = 4.0 * 2.0 * b * hkv * (d * (d + 1) + (d + 1)) \
            + itemsize * (2.0 * b * h * d               # q, o rows
                          + 2.0 * b * hkv * d)          # k, v rows
        if family == "gla_decode_fused":
            nbytes += itemsize * b * hkv                # log-decay
    elif family in ("softmax_decode_fused", "paged_decode_fused"):
        # same streaming traffic as the unfused decode kernels, minus
        # the (B, H, D) accumulator round trip the fused epilogue keeps
        # in VMEM; n is the padded context (pmax * page_size for paged)
        flops = 2.0 * 2.0 * b * h * n * d
        nbytes = itemsize * (2.0 * b * h * d              # q, o rows
                             + 2.0 * b * hkv * n * d)     # K/V stream
    else:
        raise KeyError(f"no cost model for kernel family {family!r}")
    if op == "bwd":
        flops, nbytes = 2.5 * flops, 2.0 * nbytes
    elif op == "fwdbwd":
        flops, nbytes = 3.5 * flops, 3.0 * nbytes
    elif op != "fwd":
        raise ValueError(f"op must be fwd|bwd|fwdbwd, got {op!r}")
    return {"flops": flops, "bytes": nbytes}


def kernel_roofline(flops: float, nbytes: float, time_s=None,
                    device=None) -> dict:
    """Roofline cell for one measured (or unmeasured) kernel call.

    t_roofline_s = max(flops/peak, bytes/bw) on `device` (a
    DEVICE_SPECS key; default jax.default_backend()).  achieved_frac =
    t_roofline_s / time_s — 1.0 means running AT the roofline, smaller
    is further away; None when no measurement exists (skipped cells),
    but the denominator is always present so artifact consumers can
    rely on the schema.
    """
    if device is None:
        import jax
        device = jax.default_backend()
    spec = DEVICE_SPECS.get(device, DEVICE_SPECS["cpu"])
    t_compute = flops / spec["peak_flops"]
    t_memory = nbytes / spec["mem_bw"]
    t_roof = max(t_compute, t_memory)
    return {
        "device": device,
        "flops": flops,
        "bytes": nbytes,
        "intensity": flops / nbytes if nbytes else 0.0,
        "t_roofline_s": t_roof,
        "bound": "compute" if t_compute >= t_memory else "memory",
        "achieved_frac": (t_roof / time_s
                          if time_s else None),
    }


def model_flops_for(cfg, shape) -> float:
    """6*N_active*D for a train step (3x for fwd+bwd is folded into 6N);
    2*N_active*D for inference steps (forward only).

    enc-dec: encoder params see B*enc_seq tokens, decoder (+cross +
    unembed) see the decoder tokens; decode reruns the decoder only.
    """
    n_params = cfg.active_param_count()
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    b, n = shape.global_batch, shape.seq_len
    dec_tokens = b if shape.kind == "decode" else b * n

    if cfg.family == "encdec":
        d, hd = cfg.d_model, cfg.resolved_head_dim
        attn = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads) \
            + cfg.num_heads * hd * d
        ffm = (3 if cfg.mlp_act == "swiglu" else 2) * d * cfg.d_ff
        enc_p = cfg.encoder_layers * (attn + ffm)
        dec_p = n_params - enc_p
        if shape.kind == "decode":
            return mult * dec_p * dec_tokens  # encoder state is cached
        return mult * (enc_p * b * cfg.encoder_seq + dec_p * dec_tokens)
    return mult * n_params * dec_tokens


def save_artifact(r: Roofline, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{r.arch}__{r.shape}__{r.mesh}.json")
    with open(fn, "w") as f:
        json.dump(r.to_dict(), f, indent=1)
    return fn


def load_artifacts(out_dir: str) -> list[dict]:
    rows = []
    if not os.path.isdir(out_dir):
        return rows
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                rows.append(json.load(f))
    return rows


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} "
           f"{'T_comp(s)':>10s} {'T_mem(s)':>10s} {'T_coll(s)':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'frac':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['t_compute']:10.3e} {r['t_memory']:10.3e} "
            f"{r['t_collective']:10.3e} {r['dominant']:>10s} "
            f"{r['usefulness']:7.3f} {r.get('roofline_fraction', 0):6.3f}")
    return "\n".join(lines)
