"""CLI for the autotuning subsystem.

    PYTHONPATH=src python -m repro.tune sweep --family linear \
        --impl pallas_interpret [--op fwd|fwdbwd] [--seq 256,1024]
    PYTHONPATH=src python -m repro.tune show [--cache PATH]

`sweep` measures every legal tile candidate for the requested
(family, impl) at each shape, writes each winner into the persistent
tuning cache (--cache, default artifacts/tune_cache.json), and emits
the full candidate x roofline record to --json-out
(default artifacts/BENCH_autotune.json).  `show` prints the cache.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from repro.tune.cache import DEFAULT_CACHE_PATH, TuningCache
from repro.tune.sweep import BENCH_PATH, sweep_shape

FAMILIES = ("linear", "softmax", "gla", "ssd", "paged")


def _int_list(text: str) -> list[int]:
    return [int(x) for x in text.split(",") if x]


def cmd_sweep(args) -> int:
    cache = TuningCache.load(args.cache)
    records = []
    for family in args.family:
        for n in args.seq:
            shape = {"b": args.b, "h": args.h,
                     "hkv": args.hkv or args.h, "n": n, "d": args.d}
            if family == "paged":
                shape["page_size"] = args.page_size
            op = "fwd" if family == "paged" else args.op
            records.append(sweep_shape(
                family, args.impl, shape, op=op, reps=args.reps,
                cache=cache))
    cache.save()
    print(f"tune,cache_path,{cache.path}")
    print(f"tune,cache_entries,{len(cache)}")
    doc = {"device": jax.default_backend(), "sweeps": records}
    out = args.json_out
    parent = os.path.dirname(out)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"tune,json_artifact,{out}")
    return 0


def cmd_show(args) -> int:
    cache = TuningCache.load(args.cache)
    print(f"# {cache.path}: {len(cache)} entries")
    for key in sorted(cache.entries):
        entry = cache.entries[key]
        extra = (f"  ({entry['median_ms']:.3f}ms median)"
                 if "median_ms" in entry else "")
        print(f"{key}  ->  {entry['tiles']}{extra}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="python -m repro.tune",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="sweep tile candidates, cache winners")
    sw.add_argument("--family", action="append", choices=FAMILIES,
                    required=True, help="kernel family (repeatable)")
    sw.add_argument("--impl", default="xla",
                    help="KernelImpl name (xla, pallas, pallas_interpret)")
    sw.add_argument("--op", default="fwd", choices=("fwd", "fwdbwd"),
                    help="time forward only, or forward+backward "
                         "(paged is always fwd)")
    sw.add_argument("--b", type=int, default=1)
    sw.add_argument("--h", type=int, default=8)
    sw.add_argument("--hkv", type=int, default=0,
                    help="kv heads (default: --h, i.e. MHA)")
    sw.add_argument("--d", type=int, default=64)
    sw.add_argument("--seq", type=_int_list, default=[1024],
                    help="comma-separated sequence lengths")
    sw.add_argument("--page-size", type=int, default=16)
    sw.add_argument("--reps", type=int, default=5)
    sw.add_argument("--cache", default=DEFAULT_CACHE_PATH)
    sw.add_argument("--json-out", default=BENCH_PATH)
    sw.set_defaults(fn=cmd_sweep)

    sh = sub.add_parser("show", help="print the tuning cache")
    sh.add_argument("--cache", default=DEFAULT_CACHE_PATH)
    sh.set_defaults(fn=cmd_show)
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
