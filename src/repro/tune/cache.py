"""Persistent JSON tuning cache for kernel tile sizes.

Entries are keyed by (family, impl, op, shape-bucket, dtype,
device_kind):

  * family/impl — the KernelImpl registry coordinates (kernels/ops.py);
  * op          — "fwd" or "bwd" (forward and backward kernels tile
                  independently: the flash backward's dk/dv grid has a
                  different arithmetic intensity than its forward);
  * shape-bucket — batch and sequence length rounded UP to powers of
                  two, head counts and head_dim kept exact.  b and n
                  vary continuously in serving (ragged batches, growing
                  contexts) while h/hkv/d are architectural constants;
                  bucketing keeps one sweep's winner applicable to the
                  whole bucket and makes lookups deterministic;
  * dtype       — tile legality and MXU efficiency differ by itemsize;
  * device_kind — jax.default_backend(): a CPU-interpret winner must
                  never silently apply on a TPU.

The on-disk format is versioned JSON (`SCHEMA_VERSION`); `validate`
checks a loaded document structurally and is what CI asserts against.
A missing file loads as an empty cache — with an empty cache installed,
kernel dispatch is byte-identical to the untuned defaults
(kernels/defaults.py), which a test pins.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp

SCHEMA_VERSION = 1
DEFAULT_CACHE_PATH = "artifacts/tune_cache.json"
_OPS = ("fwd", "bwd")


def device_kind() -> str:
    """The dispatch platform the cache entry was measured on."""
    return jax.default_backend()


def _bucket_pow2(x: int) -> int:
    x = max(int(x), 1)
    p = 1
    while p < x:
        p <<= 1
    return p


def shape_bucket(shape: dict) -> str:
    """Deterministic bucket string: b/n rounded up to powers of two,
    everything else exact, keys sorted."""
    parts = []
    for key in sorted(shape):
        val = int(shape[key])
        if key in ("b", "n"):
            val = _bucket_pow2(val)
        parts.append(f"{key}={val}")
    return ",".join(parts)


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


def make_key(family: str, impl: str, op: str, shape: dict, dtype,
             device: Optional[str] = None) -> str:
    if op not in _OPS:
        raise ValueError(f"op must be one of {_OPS}, got {op!r}")
    device = device or device_kind()
    return "|".join([family, impl, op, shape_bucket(shape),
                     _dtype_name(dtype), device])


@dataclasses.dataclass
class TuningCache:
    """In-memory view of one tuning-cache file.

    lookup/put take the same (family, impl, op, shape, dtype) the
    dispatch layer has at hand; the key derivation (bucketing, device
    kind) lives here so callers cannot disagree on it.
    """

    path: str = DEFAULT_CACHE_PATH
    entries: dict = dataclasses.field(default_factory=dict)

    @classmethod
    def load(cls, path: str = DEFAULT_CACHE_PATH) -> "TuningCache":
        """Load a cache file; a missing file is an empty cache."""
        if not os.path.exists(path):
            return cls(path=path)
        with open(path) as f:
            doc = json.load(f)
        errors = validate(doc)
        if errors:
            raise ValueError(
                f"invalid tuning cache {path!r}: " + "; ".join(errors))
        return cls(path=path, entries=doc["entries"])

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_doc(), f, indent=1, sort_keys=True)
        return path

    def to_doc(self) -> dict:
        return {"version": SCHEMA_VERSION, "entries": self.entries}

    def lookup(self, family: str, impl: str, op: str, shape: dict,
               dtype, device: Optional[str] = None) -> Optional[dict]:
        """Tile dict for the key, or None (dispatch then uses defaults)."""
        entry = self.entries.get(
            make_key(family, impl, op, shape, dtype, device))
        return dict(entry["tiles"]) if entry else None

    def put(self, family: str, impl: str, op: str, shape: dict, dtype,
            tiles: dict, device: Optional[str] = None, **meta) -> str:
        """Record a winner; extra keyword args (median_ms, swept, ...)
        are stored alongside for observability.  Returns the key."""
        device = device or device_kind()
        key = make_key(family, impl, op, shape, dtype, device)
        self.entries[key] = {
            "family": family, "impl": impl, "op": op,
            "shape_bucket": shape_bucket(shape),
            "dtype": _dtype_name(dtype), "device_kind": device,
            "tiles": {k: int(v) for k, v in tiles.items()},
            **meta,
        }
        return key

    def __len__(self) -> int:
        return len(self.entries)


def validate(doc) -> list[str]:
    """Structural schema check; returns a list of errors (empty = valid)."""
    errors = []
    if not isinstance(doc, dict):
        return [f"document must be an object, got {type(doc).__name__}"]
    if doc.get("version") != SCHEMA_VERSION:
        errors.append(f"version must be {SCHEMA_VERSION}, "
                      f"got {doc.get('version')!r}")
    entries = doc.get("entries")
    if not isinstance(entries, dict):
        return errors + ["entries must be an object"]
    for key, entry in entries.items():
        where = f"entries[{key!r}]"
        if not isinstance(entry, dict):
            errors.append(f"{where} must be an object")
            continue
        for field in ("family", "impl", "op", "shape_bucket", "dtype",
                      "device_kind"):
            if not isinstance(entry.get(field), str):
                errors.append(f"{where}.{field} must be a string")
        if entry.get("op") not in _OPS:
            errors.append(f"{where}.op must be one of {_OPS}")
        tiles = entry.get("tiles")
        if not isinstance(tiles, dict) or not tiles:
            errors.append(f"{where}.tiles must be a non-empty object")
        elif not all(isinstance(v, int) and v > 0 for v in tiles.values()):
            errors.append(f"{where}.tiles values must be positive ints")
        else:
            expect = "|".join([entry.get("family", ""),
                               entry.get("impl", ""), entry.get("op", ""),
                               entry.get("shape_bucket", ""),
                               entry.get("dtype", ""),
                               entry.get("device_kind", "")])
            if expect != key:
                errors.append(f"{where} key does not match its fields "
                              f"(expected {expect!r})")
    return errors
