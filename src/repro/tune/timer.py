"""Compile-excluded, device-synchronized median-of-k timing.

The ONE measurement methodology for this repo: `benchmarks/run.py`'s
entries and `repro.tune.sweep` both time through `measure`, so a number
in BENCH_flash.json is comparable to a number in BENCH_autotune.json.

Methodology (and why):

  * warmup calls run first and are never timed — jit compilation and
    autotuner-cache population happen there, not in a measured rep;
  * EVERY rep is bracketed by `jax.block_until_ready` on the rep's own
    outputs — async dispatch otherwise attributes a rep's device time
    to whoever synchronizes next;
  * the statistic is the MEDIAN of k reps, not the mean: wall-clock on
    a shared host is contaminated by one-sided outliers (GC, scheduler
    preemption), and the median is robust to them where the mean is not.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Wall-clock stats for one callable at one shape, seconds."""

    median_s: float
    mean_s: float
    min_s: float
    max_s: float
    reps: int
    warmup: int

    @property
    def median_ms(self) -> float:
        return self.median_s * 1e3

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def now() -> float:
    """Monotonic seconds for elapsed-span arithmetic (train-loop step
    timing, serve latency).  The repo's only perf_counter outside
    `measure` — repro.check lint rule REPRO-L001 keeps it that way.

    Spans measured with `now()` include async-dispatch queueing unless
    the caller synchronizes; for kernel numbers use `measure`.
    """
    return time.perf_counter()


def wallclock() -> float:
    """Epoch seconds for metadata stamps (checkpoint manifests, report
    headers) — NOT for durations; use `now()` spans for those."""
    return time.time()


def measure(fn: Callable[..., Any], *args, reps: int = 5,
            warmup: int = 1, **kwargs) -> Measurement:
    """Time `fn(*args, **kwargs)`: `warmup` untimed calls (compile),
    then `reps` calls each synchronized via block_until_ready.
    """
    if reps < 1:
        raise ValueError(f"reps must be >= 1, got {reps}")
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args, **kwargs))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append(time.perf_counter() - t0)
    return Measurement(median_s=statistics.median(ts),
                       mean_s=sum(ts) / len(ts), min_s=min(ts),
                       max_s=max(ts), reps=reps, warmup=max(warmup, 0))
