"""repro.tune — kernel autotuning + roofline observability subsystem.

The paper's headline result is kernel-level speed, yet every Pallas
block size in this repo was a hand-picked constant.  This package is
the machinery to pursue (and measure progress toward) that claim:

  timer.py   compile-excluded, device-synchronized median-of-k timing —
             the ONE measurement methodology shared by every benchmark
             (`benchmarks/run.py`) and by the sweeps here
  space.py   per-kernel-family tile search spaces (chunk, block_q/k,
             pages_per_block) with legality filtering against shape,
             dtype, and a VMEM budget
  cache.py   persistent JSON tuning cache keyed by (family, impl, op,
             shape-bucket, dtype, device_kind), schema-validated
  sweep.py   the sweep driver: measures every legal candidate through
             the REAL dispatch path (`kernels/ops.py`), caches each
             winner, and emits `artifacts/BENCH_autotune.json` with a
             roofline cell per candidate

Dispatch integration lives in `kernels/ops.py`: each KernelImpl wrapper
consults the installed cache (`ops.set_tuning_cache`) at trace time and
falls back to `kernels/defaults.py` — with no cache installed, every
kernel launches exactly as before.  Opt in per process via `activate`
/ `activate_from_cfg` (cfg.tune, `--autotune` on the launchers), or run

    PYTHONPATH=src python -m repro.tune sweep --family linear \
        --impl pallas_interpret

to populate the cache.  See docs/autotuning.md.
"""
from __future__ import annotations

from typing import Optional

from repro.tune.cache import TuningCache, shape_bucket, validate
from repro.tune.space import candidates, search_space
from repro.tune.timer import Measurement, measure

__all__ = [
    "Measurement", "measure", "TuningCache", "shape_bucket", "validate",
    "candidates", "search_space", "activate", "activate_from_cfg",
    "deactivate",
]


def activate(cache_or_path) -> TuningCache:
    """Install a tuning cache into kernel dispatch for this process.

    Accepts a TuningCache or a path to load one from (a missing file
    yields an empty cache — dispatch then behaves exactly as untuned).
    Returns the installed cache.
    """
    from repro.kernels import ops as _ops
    cache = (cache_or_path if isinstance(cache_or_path, TuningCache)
             else TuningCache.load(cache_or_path))
    _ops.set_tuning_cache(cache)
    return cache


def activate_from_cfg(cfg) -> Optional[TuningCache]:
    """Activate autotuned dispatch when cfg.tune asks for it.

    Launchers call this once after building their ModelConfig; a None
    or disabled cfg.tune is a no-op returning None.
    """
    tune_cfg = getattr(cfg, "tune", None)
    if tune_cfg is None or not tune_cfg.enabled:
        return None
    return activate(tune_cfg.cache_path)


def deactivate() -> None:
    """Remove any installed cache — dispatch falls back to defaults."""
    from repro.kernels import ops as _ops
    _ops.set_tuning_cache(None)
