"""Assert every benchmark cell carries a roofline block — CI gate.

    PYTHONPATH=src python -m repro.tune.bench_check artifacts/BENCH_*.json

A cell passes when it has a "roofline" object with a numeric
`t_roofline_s` (the denominator must exist even for skipped cells) and
an `achieved_frac` key — whose VALUE may be null for unmeasured cells
(pallas rows skipped on CPU), but whose absence means the bench entry
forgot the observability contract.  BENCH_autotune.json nests cells
under sweeps[].candidates[]; BENCH_{flash,gla,paged}.json keep them in
a top-level "cells" list.

For sweep documents the winner is part of the contract too: each sweep
must carry a "best" cell that passes the same roofline check, has a
tiles dict, and whose median_ms actually is the minimum over the
sweep's candidates — a best that no candidate backs means the sweep
and its summary were produced by different code paths.

Serving-latency documents (BENCH_serve.json, top-level kind
"serve_lat") carry percentile distributions instead of rooflines: each
cell must have "ttft_ms" and "inter_token_ms" objects with p50 AND p99
keys (values may be null — a cell whose requests never reached decode
— but absence means the bench forgot the schema) plus an "occupancy"
key.
"""
from __future__ import annotations

import json
import numbers
import sys


def check_cell(cell: dict, where: str) -> list[str]:
    errors = []
    roof = cell.get("roofline")
    if not isinstance(roof, dict):
        return [f"{where}: missing roofline object"]
    t = roof.get("t_roofline_s")
    if not isinstance(t, numbers.Real) or t <= 0:
        errors.append(f"{where}: roofline.t_roofline_s must be a "
                      f"positive number, got {t!r}")
    if "achieved_frac" not in roof:
        errors.append(f"{where}: roofline.achieved_frac key missing "
                      f"(null is fine, absence is not)")
    return errors


def check_serve_cell(cell: dict, where: str) -> list[str]:
    """One serve_lat cell: latency percentiles + occupancy present.

    Null percentile VALUES are legal (an unmeasured distribution);
    missing KEYS are the schema violation this gate exists to catch."""
    errors = []
    for key in ("ttft_ms", "inter_token_ms"):
        dist = cell.get(key)
        if not isinstance(dist, dict):
            errors.append(f"{where}: {key} must be an object with "
                          f"p50/p99 keys, got {dist!r}")
            continue
        for p in ("p50", "p99"):
            if p not in dist:
                errors.append(f"{where}: {key}.{p} key missing "
                              f"(null is fine, absence is not)")
            elif dist[p] is not None and \
                    not isinstance(dist[p], numbers.Real):
                errors.append(f"{where}: {key}.{p} must be a number "
                              f"or null, got {dist[p]!r}")
    if "occupancy" not in cell:
        errors.append(f"{where}: occupancy key missing")
    # scheduler v2: every cell must report its preemption count (0 is a
    # legal value for a priority-free workload; absence means the bench
    # predates the preemption schema)
    preempt = cell.get("preemptions", None)
    if "preemptions" not in cell:
        errors.append(f"{where}: preemptions key missing")
    elif not isinstance(preempt, numbers.Real):
        errors.append(f"{where}: preemptions must be a number, "
                      f"got {preempt!r}")
    return errors


def check_best(sweep: dict, cands: list, where: str) -> list[str]:
    """The sweep's recorded winner must be real: roofline-complete,
    tile-carrying, and the true median_ms minimum of its candidates."""
    best = sweep.get("best")
    if not isinstance(best, dict):
        return [f"{where}: missing best cell"]
    errors = check_cell(best, f"{where}.best")
    if not isinstance(best.get("tiles"), dict):
        errors.append(f"{where}.best: tiles must be an object, "
                      f"got {best.get('tiles')!r}")
    medians = [c.get("median_ms") for c in cands
               if isinstance(c.get("median_ms"), numbers.Real)]
    bm = best.get("median_ms")
    if not isinstance(bm, numbers.Real):
        errors.append(f"{where}.best: median_ms must be a number, "
                      f"got {bm!r}")
    elif medians and bm > min(medians):
        errors.append(f"{where}.best: median_ms {bm} is not the "
                      f"candidate minimum {min(medians)}")
    return errors


def check_doc(doc: dict, name: str) -> list[str]:
    errors = []
    cell_check = check_serve_cell if doc.get("kind") == "serve_lat" \
        else check_cell
    cells = doc.get("cells")
    if isinstance(cells, list):
        if not cells:
            errors.append(f"{name}: empty cells list")
        for i, cell in enumerate(cells):
            errors += cell_check(cell, f"{name} cells[{i}]")
    sweeps = doc.get("sweeps")
    if isinstance(sweeps, list):
        if not sweeps:
            errors.append(f"{name}: empty sweeps list")
        for i, sweep in enumerate(sweeps):
            cands = sweep.get("candidates", [])
            if not cands:
                errors.append(f"{name}: sweeps[{i}] has no candidates")
            for j, cand in enumerate(cands):
                errors += check_cell(
                    cand, f"{name} sweeps[{i}].candidates[{j}]")
            errors += check_best(sweep, cands, f"{name} sweeps[{i}]")
    if cells is None and sweeps is None:
        errors.append(f"{name}: neither 'cells' nor 'sweeps' present")
    return errors


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:])
    if not paths:
        print("usage: python -m repro.tune.bench_check BENCH.json ...",
              file=sys.stderr)
        return 2
    errors = []
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        errors += check_doc(doc, path)
        print(f"bench_check,{path},"
              f"{'FAIL' if any(e.startswith(path) for e in errors) else 'ok'}")
    for e in errors:
        print(f"bench_check,error,{e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
