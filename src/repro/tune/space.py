"""Per-kernel-family tile search spaces with legality filtering.

A search space maps tile-parameter names to candidate values; the
parameter names are exactly the keys `kernels/defaults.py` declares for
the family (and therefore exactly what a tuning-cache entry may carry
and what kernel dispatch will apply).  Spaces depend on the impl, not
just the family: the pallas flash kernel tunes (block_q, block_k) while
the softmax xla scan tunes its chunk size; ref oracles and the paged
gather oracle tune nothing.

`candidates` expands the space to the cross product, then drops
candidates that are illegal for the concrete (shape, dtype):

  * a tile larger than the dimension it tiles is a duplicate of the
    clamped maximum (every kernel applies `min(tile, n)`), so only the
    largest such candidate is kept;
  * the per-step VMEM footprint (streamed blocks + f32 scratch) must
    fit the budget — oversized tiles would fail to lower on real TPUs;
  * every legal list contains at least one candidate (the clamped
    family default), so a sweep can never come back empty.

Shape dicts use the keys produced by `kernels/ops.py` dispatch:
b, h, hkv, n, d (+ page_size for the paged family).
"""
from __future__ import annotations

import itertools

import jax.numpy as jnp

from repro.kernels.defaults import default_tiles

# one TPU core's VMEM is ~16 MiB; leave headroom for the pipeline's
# double-buffering of the streamed blocks
VMEM_BUDGET = 8 * 1024 * 1024

_CHUNKS = [32, 64, 128, 256, 512]
_BLOCKS = [64, 128, 256, 512]
_PPBS = [1, 2, 4, 8]


def search_space(family: str, impl: str) -> dict[str, list[int]]:
    """Tile-parameter candidate values for one (family, impl)."""
    if impl == "ref":
        return {}  # oracles take no tile parameters
    pallas = impl.startswith("pallas")
    if family in ("linear", "gla", "ssd"):
        return {"chunk": list(_CHUNKS)}
    if family == "softmax":
        if pallas:
            return {"block_q": list(_BLOCKS), "block_k": list(_BLOCKS)}
        return {"chunk": list(_CHUNKS)}
    if family == "paged":
        if pallas:
            return {"pages_per_block": list(_PPBS)}
        return {}  # the xla impl is gather-then-softmax, nothing to tile
    if family in ("linear_decode_fused", "gla_decode_fused"):
        return {}  # one-token step: the whole state page IS the tile
    if family == "softmax_decode_fused":
        return {"block_k": list(_BLOCKS)} if pallas else {}
    if family == "paged_decode_fused":
        return {"pages_per_block": list(_PPBS)} if pallas else {}
    raise KeyError(f"no search space for kernel family {family!r}")


def vmem_bytes_estimate(family: str, cand: dict, shape: dict) -> int:
    """f32 bytes resident per grid step: streamed blocks + scratch.

    A structural estimate (the compiler may fuse or double-buffer), used
    only to reject clearly-oversized tiles before a sweep wastes time on
    them or a TPU lowering rejects them.
    """
    d = shape["d"]
    if family in ("linear", "gla", "ssd"):
        c = cand.get("chunk", 128)
        # q, k, v, o blocks (c, d); g/ld vectors (c,); state (d, d+1)
        return 4 * (4 * c * d + 2 * c + d * (d + 1))
    if family == "softmax":
        bq = cand.get("block_q", 128)
        bk = cand.get("block_k", 128)
        c = cand.get("chunk", 0)
        if c:  # xla scan: per-chunk probability block
            return 4 * (c * shape["n"] + 3 * c * d)
        # q/o/acc blocks (bq, d), k/v blocks (bk, d), m/l vectors
        return 4 * (3 * bq * d + 2 * bk * d + 2 * bq)
    if family == "paged":
        ps = shape.get("page_size", 16)
        ppb = cand.get("pages_per_block", 1)
        # ppb K and V page blocks (ps, d) + q/acc rows
        return 4 * (2 * ppb * ps * d + 2 * d)
    if family in ("linear_decode_fused", "gla_decode_fused"):
        # state page (d, d+1) + normalizer (d+1) + q group / k / v / o rows
        g = max(shape.get("h", 1) // max(shape.get("hkv", 1), 1), 1)
        return 4 * (d * (d + 1) + (d + 1) + (2 * g + 2) * d)
    if family == "softmax_decode_fused":
        bk = cand.get("block_k", 128)
        g = max(shape.get("h", 1) // max(shape.get("hkv", 1), 1), 1)
        # k/v blocks (bk, d) + q/o/acc group rows + m/l vectors
        return 4 * (2 * bk * d + 3 * g * d + 2 * g)
    if family == "paged_decode_fused":
        ps = shape.get("page_size", 16)
        ppb = cand.get("pages_per_block", 1)
        g = max(shape.get("h", 1) // max(shape.get("hkv", 1), 1), 1)
        return 4 * (2 * ppb * ps * d + (3 * g + 2) * d)
    raise KeyError(f"no VMEM model for kernel family {family!r}")


def _tiled_extent(family: str, param: str, shape: dict) -> int:
    """The extent the parameter tiles — values above it are clamps."""
    if param == "pages_per_block":
        ps = max(shape.get("page_size", 16), 1)
        return max(-(-shape["n"] // ps), 1)
    return max(shape["n"], 1)


def candidates(family: str, impl: str, shape: dict, dtype=jnp.float32,
               vmem_budget: int = VMEM_BUDGET) -> list[dict]:
    """Legal tile assignments for one (family, impl, shape, dtype).

    Returns a list of dicts (possibly a single empty dict for untiled
    impls), deduplicated after clamping each parameter to the extent it
    tiles, VMEM-filtered, and guaranteed non-empty: the clamped family
    default is always included.
    """
    space = search_space(family, impl)
    if not space:
        return [{}]
    params = sorted(space)
    seen, out = set(), []

    def consider(cand: dict):
        clamped = {p: min(v, _tiled_extent(family, p, shape))
                   for p, v in cand.items()}
        key = tuple(sorted(clamped.items()))
        if key in seen:
            return
        seen.add(key)
        if vmem_bytes_estimate(family, clamped, shape) <= vmem_budget:
            out.append(clamped)

    for values in itertools.product(*(space[p] for p in params)):
        consider(dict(zip(params, values)))
    if not out:
        # every swept tile blew the budget: fall back to the clamped
        # default so the sweep (and dispatch) always has a candidate
        defaults = {p: v for p, v in default_tiles(family).items()
                    if p in space}
        out.append({p: min(v, _tiled_extent(family, p, shape))
                    for p, v in defaults.items()})
    return out
