"""Sweep driver: measure every legal tile candidate through the REAL
dispatch path and cache each winner.

For each candidate the driver installs a single-purpose override cache
(`_FixedTiles`) via `ops.set_tuning_cache`, builds a FRESH `jax.jit`
(tile resolution happens at trace time, and jit caches traces — reusing
a jitted callable would silently reuse the first candidate's tiles),
measures with `tune.timer.measure`, and restores the previous cache.
The winner by median wall-clock goes into the persistent `TuningCache`
under op "fwd" (and "bwd" too for `op="fwdbwd"` sweeps — the joint
measurement picks one tile pair for the training step).

Every candidate row carries a roofline cell
(`analysis.roofline.kernel_roofline` over the family's structural
costs), so `artifacts/BENCH_autotune.json` doubles as the observability
artifact: achieved-vs-roofline fraction per (family, impl, shape,
candidate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.roofline import attention_costs, kernel_roofline
from repro.kernels import ops
from repro.tune.cache import TuningCache, shape_bucket
from repro.tune.space import candidates
from repro.tune.timer import measure

BENCH_PATH = "artifacts/BENCH_autotune.json"


class _FixedTiles:
    """Override cache answering every lookup with one tile dict —
    routes a sweep candidate through the production dispatch path."""

    def __init__(self, tiles: dict):
        self.tiles = dict(tiles)

    def lookup(self, *args, **kwargs):
        return dict(self.tiles) if self.tiles else None


def _qkv(shape: dict, dtype, key: int = 0):
    b, h, hkv = shape["b"], shape["h"], shape["hkv"]
    n, d = shape["n"], shape["d"]
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    q = (jax.random.normal(ks[0], (b, h, n, d)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (b, hkv, n, d)) * 0.3).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, n, d)).astype(dtype)
    return ks, q, k, v


def build_problem(family: str, impl: str, shape: dict, op: str,
                  dtype=jnp.float32):
    """(callable, args) measuring one op of one family through ops.*.

    The callable is UNJITTED — the sweep wraps it in a fresh jax.jit
    per candidate.  op "fwd" times the forward; "fwdbwd" times
    grad-of-sum (forward + custom-vjp backward together).
    """
    ks, q, k, v = _qkv(shape, dtype)
    # custom_vjp entry points take no keyword args — pass positionally;
    # the caller-level chunk below is only the fallback and is shadowed
    # by the sweep's override cache
    if family == "linear":
        def fwd(q, k, v):
            return ops.la_causal(q, k, v, 1.0, 1.0, ops.DEFAULT_CHUNK,
                                 impl)
        args = (q, k, v)
    elif family == "softmax":
        def fwd(q, k, v):
            return ops.softmax_attention(q, k, v, backend=impl)
        args = (q, k, v)
    elif family == "gla":
        ld = -jax.nn.softplus(
            jax.random.normal(ks[3], (shape["b"], shape["hkv"],
                                      shape["n"]))).astype(dtype)

        def fwd(q, k, v, ld):
            return ops.gla_causal(q, k, v, ld, 1.0, 1.0, 128, impl)
        args = (q, k, v, ld)
    elif family == "ssd":
        # q, k shared per group (hkv groups); v and decay carry h heads
        qg = (jax.random.normal(ks[0], (shape["b"], shape["hkv"],
                                        shape["n"], shape["d"]))
              * 0.3).astype(dtype)
        vh = jax.random.normal(ks[2], (shape["b"], shape["h"],
                                       shape["n"], shape["d"]))
        ld = -jax.nn.softplus(
            jax.random.normal(ks[3], (shape["b"], shape["h"],
                                      shape["n"]))).astype(dtype)

        def fwd(q, k, v, ld):
            return ops.ssd_causal(q, k, v, ld, 128, impl)
        args = (qg, k, vh.astype(dtype), ld)
    elif family == "paged":
        if op != "fwd":
            raise ValueError("paged decode is inference-only (op=fwd)")
        ps = shape.get("page_size", 16)
        b, h, hkv, d = shape["b"], shape["h"], shape["hkv"], shape["d"]
        pmax = max(-(-shape["n"] // ps), 1)
        num_pages = b * pmax + 1
        qd = (jax.random.normal(ks[0], (b, h, 1, d)) * 0.3).astype(dtype)
        kp = (jax.random.normal(ks[1], (num_pages, hkv, ps, d))
              * 0.3).astype(dtype)
        vp = jax.random.normal(ks[2], (num_pages, hkv, ps, d)).astype(dtype)
        pt = jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
        lens = jnp.full((b,), pmax * ps, jnp.int32)

        def fwd(q):
            return ops.paged_attention(q, kp, vp, pt, lens, backend=impl)
        args = (qd,)
    elif family in ("linear_decode_fused", "gla_decode_fused"):
        if op != "fwd":
            raise ValueError("fused decode is inference-only (op=fwd)")
        b, h, hkv, d = shape["b"], shape["h"], shape["hkv"], shape["d"]
        qd = (jax.random.normal(ks[0], (b, h, d)) * 0.3).astype(dtype)
        kd = (jax.random.normal(ks[1], (b, hkv, d)) * 0.3).astype(dtype)
        vd = jax.random.normal(ks[2], (b, hkv, d)).astype(dtype)
        st = ops.init_state(b, hkv, d, d)
        if family == "gla_decode_fused":
            st = ops.init_gla_state(b, hkv, d, d)
            ld = -jax.nn.softplus(
                jax.random.normal(ks[3], (b, hkv))).astype(jnp.float32)

            def fwd(q, k, v):
                # only o: the f32 carried state is not a kernel output
                # precision claim (it is f32 by contract)
                return ops.gla_decode_step_fused(st, q, k, v, ld,
                                                 backend=impl)[1]
        else:
            def fwd(q, k, v):
                return ops.la_decode_step_fused(st, q, k, v,
                                                backend=impl)[1]
        args = (qd, kd, vd)
    elif family == "softmax_decode_fused":
        if op != "fwd":
            raise ValueError("fused decode is inference-only (op=fwd)")
        b, h, hkv = shape["b"], shape["h"], shape["hkv"]
        n, d = shape["n"], shape["d"]
        qd = (jax.random.normal(ks[0], (b, h, 1, d)) * 0.3).astype(dtype)
        kc = (jax.random.normal(ks[1], (b, hkv, n, d)) * 0.3).astype(dtype)
        vc = jax.random.normal(ks[2], (b, hkv, n, d)).astype(dtype)
        lens = jnp.full((b,), n, jnp.int32)

        def fwd(q):
            return ops.softmax_decode_fused(q, kc, vc, lens, backend=impl)
        args = (qd,)
    elif family == "paged_decode_fused":
        if op != "fwd":
            raise ValueError("fused decode is inference-only (op=fwd)")
        ps = shape.get("page_size", 16)
        b, h, hkv, d = shape["b"], shape["h"], shape["hkv"], shape["d"]
        pmax = max(-(-shape["n"] // ps), 1)
        num_pages = b * pmax + 1
        qd = (jax.random.normal(ks[0], (b, h, 1, d)) * 0.3).astype(dtype)
        kp = (jax.random.normal(ks[1], (num_pages, hkv, ps, d))
              * 0.3).astype(dtype)
        vp = jax.random.normal(ks[2], (num_pages, hkv, ps, d)).astype(dtype)
        pt = jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
        lens = jnp.full((b,), pmax * ps, jnp.int32)

        def fwd(q):
            return ops.paged_attention_fused(q, kp, vp, pt, lens,
                                             backend=impl)
        args = (qd,)
    else:
        raise KeyError(f"no sweep problem for kernel family {family!r}")

    if op == "fwd":
        return fwd, args
    if op == "fwdbwd":
        argnums = tuple(range(len(args)))
        return jax.grad(lambda *a: jnp.sum(fwd(*a)), argnums=argnums), args
    raise ValueError(f"op must be fwd|fwdbwd, got {op!r}")


def sweep_shape(family: str, impl: str, shape: dict, *, op: str = "fwd",
                reps: int = 5, warmup: int = 1, dtype=jnp.float32,
                cache: TuningCache | None = None, log=print) -> dict:
    """Sweep all legal candidates at one shape; record the winner.

    Returns the BENCH_autotune record for this (family, impl, shape):
    one row per candidate with tiles, timing, and a roofline cell.
    """
    cands = candidates(family, impl, shape, dtype)
    costs = attention_costs(family, shape, op=op)
    rows = []
    for cand in cands:
        prev = ops.set_tuning_cache(_FixedTiles(cand) if cand else None)
        try:
            fn, args = build_problem(family, impl, shape, op, dtype)
            m = measure(jax.jit(fn), *args, reps=reps, warmup=warmup)
        finally:
            ops.set_tuning_cache(prev)
        roof = kernel_roofline(costs["flops"], costs["bytes"],
                               time_s=m.median_s)
        rows.append({"tiles": cand, "median_ms": round(m.median_ms, 4),
                     "min_ms": round(m.min_s * 1e3, 4),
                     "roofline": roof})
        log(f"tune,{family}.{impl}.{op},{shape_bucket(shape)},"
            f"{cand},{m.median_ms:.3f}ms")
    best = min(rows, key=lambda r: r["median_ms"])
    record = {"family": family, "impl": impl, "op": op,
              "shape": dict(shape), "shape_bucket": shape_bucket(shape),
              "dtype": jnp.dtype(dtype).name, "candidates": rows,
              "best": best}
    if cache is not None and best["tiles"]:
        cache_ops = ("fwd", "bwd") if op == "fwdbwd" else (op,)
        for cop in cache_ops:
            cache.put(family, impl, cop, shape, dtype, best["tiles"],
                      median_ms=best["median_ms"], swept=len(rows),
                      swept_op=op)
    return record
