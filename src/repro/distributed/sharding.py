"""Sharding rules: parameters, optimizer state, activations, batches.

Strategy (designed for 1000+ chips; validated on the 256/512-chip
dry-run meshes):

* Parameters — 2D "FSDP x TP": for every >=2D weight, the two largest
  dims are sharded over ("data", "model") — largest over the axis with
  more headroom — so a 236B-param model fits per-device HBM.  The
  "pod" axis (multi-pod mesh) replicates params; gradients all-reduce
  over it (classic cross-pod DP).  1D params (norm scales, biases)
  replicate.
* Expert weights (E, d_in, d_out) — experts over "model" (expert
  parallelism), d over "data".
* Optimizer state — same PartitionSpec as its param (ZeRO-style: the
  FSDP dim already shards moments 16-way; see distributed/zero.py).
* Batches — leading batch dim over ("pod", "data") when divisible,
  else over whatever prefix divides (long_500k has batch 1 ->
  replicated; its parallelism comes from TP).

Divisibility is always checked against the actual mesh axis sizes;
non-divisible dims fall back to the next candidate axis or replicate.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 1


def param_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    """PartitionSpec for one parameter given its tree path and shape.

    On the multi-pod mesh the FSDP dim extends over ("pod", "data") —
    a 236B model's f32 master+moments would not fit 16 GB/chip with the
    pod axis pure-DP (grads still reduce over pod; XLA emits
    reduce-scatter + all-gather instead of all-reduce).
    """
    pod = _axis_size(mesh, "pod")
    data_ax = "data" if "data" in mesh.shape else None
    if pod > 1 and data_ax:
        data_ax = ("pod", "data")
    model_ax = "model" if "model" in mesh.shape else None
    d = _axis_size(mesh, "data") * pod
    m = _axis_size(mesh, "model")

    if len(shape) <= 1:
        return P()

    # embedding tables: shard the vocab dim over "model" when it
    # divides; otherwise keep d_model UNSHARDED on "model" (a d-sharded
    # table turns every token gather into a cross-shard dynamic-slice —
    # XLA's partitioner rejects it inside the grad-accumulation scan)
    # and fall back to FSDP on d over "data".
    if "embed" in path and len(shape) == 2:
        v_dim, d_dim = shape
        if v_dim % m == 0:
            return P("model", "data" if d_dim % d == 0 else None)
        return P(None, "data" if d_dim % d == 0 else None)

    # stacked-layer / stacked-expert leading dims: never shard the layer
    # axis (scan iterates it); shard experts over model.
    spec = [None] * len(shape)
    dims = list(range(len(shape)))
    is_expert = "experts" in path or "shared" in path
    if "blocks" in path or "groups" in path or "tail" in path:
        # leading stacked-layer dim(s): (L, ...) or (G, P, ...)
        lead = 2 if "groups" in path else 1
        dims = dims[lead:]
    if is_expert and len(dims) >= 3:
        e_dim = dims[0]
        if shape[e_dim] % m == 0:
            spec[e_dim] = model_ax
        rest = dims[1:]
        # FSDP over the largest remaining dim
        rest_sorted = sorted(rest, key=lambda i: -shape[i])
        for i in rest_sorted:
            if shape[i] % d == 0:
                spec[i] = data_ax
                break
        return P(*spec)

    if not dims:
        return P(*spec)
    # generic 2D+ weight: model-shard the largest dim, data-shard (FSDP)
    # the second largest; fall back / skip when not divisible.
    order = sorted(dims, key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and model_ax and shape[i] % m == 0:
            spec[i] = model_ax
            break
    for i in order:
        if spec[i] is None and data_ax and shape[i] % d == 0:
            spec[i] = data_ax
            break
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def param_shardings(params_shape, mesh: Mesh):
    """NamedShardings for a params pytree (of arrays or ShapeDtypeStructs)."""
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path), leaf.shape,
                                              mesh))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_axes(mesh: Mesh) -> tuple:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes


def batch_spec(shape: tuple, mesh: Mesh, batch_dim: int = 0) -> P:
    """Shard the batch dim over ("pod","data") — as much as divides."""
    axes = batch_axes(mesh)
    b = shape[batch_dim]
    chosen = []
    prod = 1
    for a in axes:
        if b % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    spec = [None] * len(shape)
    if chosen:
        spec[batch_dim] = tuple(chosen) if len(chosen) > 1 else chosen[0]
    return P(*spec)


def batch_shardings(batch_shape, mesh: Mesh):
    """Shardings for an input-batch pytree.

    tokens/labels/frames: batch-dim 0; vlm positions (3, B, N): batch-dim 1.
    """
    def one(path, leaf):
        p = _path_str(path)
        bdim = 1 if p.startswith("positions") else 0
        return NamedSharding(mesh, batch_spec(leaf.shape, mesh, bdim))
    return jax.tree_util.tree_map_with_path(one, batch_shape)


def _cache_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    """Decode-cache leaves: (L, B, H, ...) or (G, P, B, ...) or (B, ...).

    Stacked layer dims are never sharded (scan iterates them); batch
    shards over ("pod","data"); the head dim (right after batch) shards
    over "model" when divisible.
    """
    if len(shape) == 0:
        return P()
    lead = 0
    if any(s in path for s in ("blocks", "self", "cross", "shared",
                               "tail")):
        lead = 1
    if "mamba" in path:
        lead = 2
    if len(shape) <= lead:
        return P()
    spec = [None] * len(shape)
    bdim = lead
    bspec = batch_spec((shape[bdim],), mesh, 0)[0]
    spec[bdim] = bspec
    # shard the head dim over model when divisible (dim after batch)
    m = _axis_size(mesh, "model")
    if len(shape) > bdim + 1 and shape[bdim + 1] % m == 0 and m > 1:
        spec[bdim + 1] = "model"
    return P(*spec)


def cache_shardings(cache_shape, mesh: Mesh):
    """Shardings for a decode-cache pytree (model.init_cache structure)."""
    def one(path, leaf):
        return NamedSharding(mesh, _cache_spec(_path_str(path), leaf.shape,
                                               mesh))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def activation_spec(mesh: Mesh, batch: int, with_model: bool = False) -> P:
    """(B, N, D) activations: batch over ("pod","data")."""
    bspec = batch_spec((batch,), mesh, 0)[0]
    return P(bspec, None, "model" if with_model else None)
