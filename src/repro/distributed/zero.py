"""ZeRO-style optimizer-state sharding.

With the 2D FSDP x TP param layout (distributed/sharding.py) the Adam
moments inherit the param spec — already sharded data*model-way.  For
params that could NOT be data-sharded (small or non-divisible dims),
this module adds a ZeRO-1 pass: their f32 moments are sharded over the
"data" axis on the largest divisible dim, cutting replicated optimizer
memory by the data-parallel degree.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import _path_str, param_spec


def _flat_axes(spec) -> set:
    out = set()
    for s in spec:
        if isinstance(s, tuple):
            out.update(s)
        elif s is not None:
            out.add(s)
    return out


def moment_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    base = param_spec(path, shape, mesh)
    if "data" in _flat_axes(base) or "data" not in mesh.shape \
            or len(shape) < 1:
        return base
    d = mesh.shape["data"]
    spec = list(base) + [None] * (len(shape) - len(base))
    # find the largest dim not already sharded that divides by data
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % d == 0 and shape[i] >= d:
            spec[i] = "data"
            break
    return P(*spec)


def opt_state_shardings(opt_state_shape, mesh: Mesh):
    """NamedShardings for an AdamWState pytree (step replicated)."""
    def one(path, leaf):
        if leaf.ndim == 0:  # step counter
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, moment_spec(_path_str(path), leaf.shape,
                                               mesh))
    return jax.tree_util.tree_map_with_path(one, opt_state_shape)
