"""Activation sharding constraints (MaxText-style).

XLA's automatic sharding propagation is free to re-partition activations
between ops; without anchors its CPU/dry-run cost model happily
replicates the batch dim (observed: 41 GB/device temp on a 2-layer
model).  This module provides `constrain(x, *spec)` which model code
calls at block boundaries; it is a no-op unless a policy is installed
(tests and single-device examples never notice it).

The policy is installed by launch/dryrun.py & launch/train.py via
`use_activation_policy(mesh)`: batch dims map to ("pod","data"), the
model/tensor dim of logits and per-head activations to "model".
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

BATCH = "__batch__"      # placeholder resolved to ("pod","data") subset
MODEL = "__model__"


def _resolve(mesh: Mesh, dim_size, token):
    if token == BATCH:
        axes = [a for a in ("pod", "data") if a in mesh.shape]
        prod = 1
        chosen = []
        for a in axes:
            if dim_size is not None and dim_size % (prod * mesh.shape[a]) == 0:
                chosen.append(a)
                prod *= mesh.shape[a]
        if not chosen:
            return None
        return tuple(chosen) if len(chosen) > 1 else chosen[0]
    if token == MODEL:
        if "model" in mesh.shape and dim_size is not None \
                and dim_size % mesh.shape["model"] == 0:
            return "model"
        return None
    return token


@contextlib.contextmanager
def use_activation_policy(mesh: Mesh):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def current_mesh():
    """The installed policy mesh, or None (single-device tests)."""
    return getattr(_STATE, "mesh", None)


def constrain(x, *spec):
    """with_sharding_constraint if a policy mesh is installed, else x.

    spec entries: BATCH, MODEL, None, or literal axis names; resolved
    against the dim size (non-divisible dims fall back to replicated).
    """
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return x
    resolved = tuple(_resolve(mesh, x.shape[i], s)
                     for i, s in enumerate(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
