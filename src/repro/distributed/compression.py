"""Int8 gradient compression with error feedback for cross-pod all-reduce.

At multi-pod scale the pod axis rides on DCN (slow links); compressing
the gradient all-reduce over it 4x (f32 -> int8 + per-tensor scale) cuts
the collective term.  Error feedback (Seide et al., 1-bit SGD lineage)
accumulates the quantization residual locally so compression error does
not bias convergence.

Usage (inside a shard_map'd or pjit'd step):
    grads, err = compressed_psum(grads, err, axis_name="pod")
The quantize/dequantize are pure-jnp and run fused around lax.psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_int8(x):
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    xf = x.astype(F32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(F32) * scale


def compress_decompress(x):
    """Round-trip (what the wire sees) — used for tests and the jit path
    where the collective itself is inserted by XLA."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s)


def compressed_psum(grads, err, axis_name: str):
    """psum(grads) over `axis_name` with int8 payload + error feedback.

    grads, err: matching pytrees.  Returns (synced_grads, new_err).
    """
    def one(g, e):
        gf = g.astype(F32) + e
        q, s = quantize_int8(gf)
        sent = dequantize_int8(q, s)
        new_e = gf - sent
        # int8 payloads sum exactly; scales are averaged — psum both
        total = jax.lax.psum(sent, axis_name)
        n = jax.lax.psum(jnp.ones((), F32), axis_name)
        return (total / n).astype(g.dtype), new_e

    out = jax.tree.map(one, grads, err)
    synced = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return synced, new_err
