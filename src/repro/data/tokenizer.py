"""Byte-level tokenizer (self-contained; no external vocab files).

Used by the examples and the Wiki-40B-style training driver: UTF-8 bytes
with offsets for special tokens.  A production deployment would swap in
a BPE tokenizer; the data pipeline only needs encode/decode + vocab_size.
"""
from __future__ import annotations

PAD, BOS, EOS = 0, 1, 2
SPECIALS = 3


class ByteTokenizer:
    vocab_size = 256 + SPECIALS

    def encode(self, text: str, bos: bool = True, eos: bool = False):
        ids = [b + SPECIALS for b in text.encode("utf-8")]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        data = bytes(i - SPECIALS for i in ids if i >= SPECIALS)
        return data.decode("utf-8", errors="replace")
