"""Token data pipeline: synthetic + memmap sources, per-host sharding,
background prefetch.

At 1000+ nodes each host reads only its shard of the global batch
(process_index-strided windows); the arrays produced here are the
per-host slice which launch/train.py turns into a globally-sharded
jax.Array via make_array_from_process_local_data.
"""
from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class SyntheticLM:
    """Deterministic synthetic token stream (Zipf-ish marginals).

    Reproducible across restarts: batch `i` depends only on (seed, i),
    which is what lets a resumed job replay the exact stream.
    """

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.host_batch = global_batch // jax.process_count()

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1

    def batch_at(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, i, jax.process_index()]))
        # Zipf-like marginal over the vocab, cheap to sample
        u = rng.random((self.host_batch, self.seq))
        toks = ((self.vocab - 1) * u ** 3).astype(np.int32) + 1
        return toks


class MemmapLM:
    """Flat binary token file (np.int32), strided across hosts.

    Window w of host h starts at ((w * hosts + h) * host_batch * seq)
    tokens, wrapping modulo file length — the standard "each host owns a
    disjoint stride" layout.
    """

    def __init__(self, path: str, seq_len: int, global_batch: int):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq_len
        self.global_batch = global_batch
        self.host_batch = global_batch // jax.process_count()

    def batch_at(self, i: int) -> np.ndarray:
        need = self.host_batch * self.seq
        start = ((i * jax.process_count() + jax.process_index()) * need) \
            % max(len(self.data) - need, 1)
        return np.asarray(self.data[start:start + need]).reshape(
            self.host_batch, self.seq)

    def __iter__(self):
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


class Prefetcher:
    """Background-thread prefetch of a batch iterator (depth-bounded)."""

    def __init__(self, it, depth: int = 2):
        self.q = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass
