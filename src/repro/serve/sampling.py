"""Per-request sampling, vectorized inside the jitted decode step.

Every decoding slot carries its own (temperature, top_k, top_p, PRNG
key); `sample` applies all of them in ONE batched computation so the
engine's single jitted decode step honors per-request sampling without
per-slot host round-trips.  Greedy slots (temperature <= 0) take the
exact `argmax` path — a greedy request's tokens are bitwise identical
to argmax decoding regardless of what its batch neighbors sample.

Keys are per-request (derived from `SamplingParams.seed`, or from the
engine seed + request id), so a request's sample stream is reproducible
independent of batch composition, admission order, or its slot index.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode-time sampling controls.

    temperature <= 0 means greedy (argmax); top_k <= 0 and top_p >= 1
    disable their filters.  `stop` lists extra stop-token ids (the
    engine's eos_id always stops); `seed` pins the request's PRNG stream
    (None: derived from the engine seed and the request id).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop: Tuple[int, ...] = ()
    seed: Optional[int] = None


def filter_logits(logits, top_k, top_p):
    """Mask logits outside the per-row top-k / nucleus (top-p) sets.

    logits: (B, V) f32; top_k: (B,) int32 (<= 0 disables); top_p: (B,)
    f32 (>= 1 disables).  Returns (B, V) with filtered entries at -inf.
    The top-1 token always survives, so the filters can never produce an
    all--inf row.
    """
    v = logits.shape[-1]
    desc = jnp.sort(logits, axis=-1)[..., ::-1]                    # (B, V)
    k_eff = jnp.where(top_k > 0, jnp.minimum(top_k, v), v)
    kth = jnp.take_along_axis(desc, (k_eff - 1)[:, None], axis=-1)  # (B, 1)
    keep = logits >= kth
    # nucleus: keep tokens while the EXCLUSIVE cumulative mass < p, so
    # the first token is always kept and mass crosses p inclusively
    probs = jax.nn.softmax(desc, axis=-1)
    excl = jnp.cumsum(probs, axis=-1) - probs
    p_eff = jnp.where(top_p >= 1.0, 2.0, top_p)                    # disable
    kept_sorted = excl < p_eff[:, None]
    kept_sorted = kept_sorted.at[..., 0].set(True)  # top-1 survives p=0
    thresh = jnp.min(jnp.where(kept_sorted, desc, jnp.inf), axis=-1)
    keep = keep & (logits >= thresh[:, None])
    return jnp.where(keep, logits, -jnp.inf)


def sample(logits, keys, temperature, top_k, top_p):
    """One sampling step for a batch of slots (jit-safe).

    logits: (B, V); keys: (B, 2) uint32 per-slot PRNG keys; temperature /
    top_p: (B,) f32; top_k: (B,) int32.  Returns (tokens (B,) int32,
    keys (B, 2)).  Rows with temperature <= 0 return the exact argmax.

    An ALL-greedy batch (the serving default) takes a `lax.cond` fast
    path: no PRNG split, no filter/softmax/gumbel work — just the
    argmax — and the keys pass through UNCHANGED (greedy rows never
    consume randomness, so advancing their keys bought nothing).  In a
    mixed batch every row's key advances, so a sampling request's
    stream depends only on its own key, never on its batch neighbors.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _all_greedy(_):
        return greedy, keys

    def _mixed(_):
        split = jax.vmap(jax.random.split)(keys)       # (B, 2, 2)
        new_keys, subs = split[:, 0], split[:, 1]
        filt = filter_logits(logits.astype(F32), top_k, top_p)
        scaled = filt / jnp.maximum(temperature, 1e-6)[:, None]
        drawn = jax.vmap(jax.random.categorical)(subs,
                                                 scaled).astype(jnp.int32)
        return jnp.where(temperature > 0, drawn, greedy), new_keys

    return jax.lax.cond(jnp.all(temperature <= 0.0), _all_greedy, _mixed,
                        None)


def request_key(sp: SamplingParams, engine_seed: int, rid: int):
    """The request's root PRNG key: its own seed, or engine seed x rid."""
    if sp.seed is not None:
        return jax.random.PRNGKey(sp.seed)
    return jax.random.fold_in(jax.random.PRNGKey(engine_seed), rid)
