"""Serving-cache accounting: the paper's memory story, quantified.

For a context of length S the softmax backend needs a KV cache of
O(S * Hkv * hd) per layer, while the paper's linear backend keeps a
recurrent state of O(Hkv * Dk * (Dv+1)) — independent of S.  These
functions compute exact byte counts for benchmarks/run.py (Table 1) and
the serving engine's admission control.  `cache_bytes` is exact for ANY
registered backend: it eval_shapes the backend's own `init_cache`
through the model, so new backends are accounted for automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as mdl
from repro.models.common import dtype_of


def _cache_itemsize(cfg) -> int:
    """KV-cache element bytes: the engine allocates in compute dtype."""
    return jnp.zeros((), dtype_of(cfg.compute_dtype)).dtype.itemsize


def cache_bytes(cfg, batch: int, max_len: int) -> int:
    """Exact decode-cache bytes for (cfg, batch, context)."""
    shapes = jax.eval_shape(lambda: mdl.init_cache(cfg, batch, max_len))
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(shapes))


def per_slot_bytes(cfg, max_len: int) -> int:
    """Exact MARGINAL decode-cache bytes of one extra concurrent
    sequence at this context — the unit the ByteBudget admission policy
    spends.  Softmax pays O(max_len) per slot; the paper's linear state
    is O(D^2) regardless of max_len.

    GQA-exact by construction: the eval_shape walks the backend's own
    init_cache, whose KV leaves are (B, Hkv, S, hd) — grouped-query
    softmax slots are charged for their Hkv KV heads, never the H query
    heads (regression-tested in tests/test_serving.py)."""
    return cache_bytes(cfg, 2, max_len) - cache_bytes(cfg, 1, max_len)


def state_page_bytes(cfg) -> int:
    """Bytes one GLA STATE page costs across all layers: a page holds a
    whole (Hkv, Dk, Dv+1) + (Hkv, Dv+1) decayed recurrent state in f32
    (mixers.cache.PagedGLAState) — independent of page_size, because a
    state page is one slot's O(D^2) state, not a run of KV rows."""
    hd = cfg.resolved_head_dim
    per_layer = cfg.num_kv_heads * ((hd + 1) * hd + (hd + 1))
    return per_layer * 4 * cfg.num_layers


def page_bytes(cfg, page_size: int, dtype_bytes: int | None = None) -> int:
    """Bytes one page costs across all layers — the unit PagedAdmission
    spends (page tables are int32 noise and are not charged).

    Softmax (KV pages): 2 (k and v) * page_size * Hkv * hd * itemsize
    per layer.  GLA (state pages): one whole recurrent state per page,
    page_size-independent (`state_page_bytes`).  Dispatches on the
    config's resolved backend so both admission policies price the
    arena a backend will actually allocate."""
    from repro.mixers.base import resolve_backend_name
    if resolve_backend_name(cfg) == "gla":
        return state_page_bytes(cfg)
    hd = cfg.resolved_head_dim
    if dtype_bytes is None:
        dtype_bytes = _cache_itemsize(cfg)
    return (2 * page_size * cfg.num_kv_heads * hd * dtype_bytes
            * cfg.num_layers)


def kv_cache_bytes_analytic(cfg, batch: int, seq: int,
                            dtype_bytes: int | None = None) -> int:
    """Softmax-backend KV cache: B * Hkv * S * hd * 2 (k and v) per layer.

    dtype_bytes resolves from cfg.compute_dtype (what the engine
    actually allocates); the old hardcoded 2-byte default disagreed
    with f32 caches by 2x — on the group-2 smoke configs that made the
    "analytic" number coincide with an H-head bf16 cache, reading like
    a GQA over-charge that per_slot_bytes (eval_shape-exact, Hkv-
    correct) never actually had."""
    hd = cfg.resolved_head_dim
    if dtype_bytes is None:
        dtype_bytes = _cache_itemsize(cfg)
    return (2 * batch * cfg.num_kv_heads * seq * hd * dtype_bytes
            * cfg.num_layers)


def la_state_bytes_analytic(cfg, batch: int, dtype_bytes: int = 4) -> int:
    """Paper's LA state: B * Hkv * Dk * (Dv+1) + B * Hkv * (Dv+1), f32."""
    hd = cfg.resolved_head_dim
    per_layer = batch * cfg.num_kv_heads * ((hd + 1) * hd + (hd + 1))
    return per_layer * dtype_bytes * cfg.num_layers
