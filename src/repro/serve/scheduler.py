"""Request lifecycle + admission control for the serving engine.

The paper's serving story is that the linear backend decodes from an
O(D^2) recurrent state while the softmax baseline drags an O(S) KV
cache; a fixed `max_slots` admission ignores that difference entirely.
Admission is therefore a pluggable policy that resolves the slot count
from the model config:

  FixedSlots(n)   the classic continuous-batching engine: n slots.
  ByteBudget(b)   admit while the per-slot decode-cache cost (exact,
                  from serve/cache.py's eval_shape accounting) fits an
                  HBM byte budget — the budget resolves PER BACKEND
                  automatically, so at the same budget the linear /
                  mamba2 backends run orders of magnitude more
                  concurrent sequences than softmax.
  PagedAdmission  (serve/paging.py) softmax + cfg.paging: the budget
                  buys an arena of fixed-size KV pages and requests are
                  admitted by the pages they ACTUALLY need, not a
                  worst-case max_len charge — long contexts stay
                  admissible until the arena is truly full.

SCHEDULER V2 (docs/serving.md "Scheduler v2"): the queue is a priority
queue (higher `Request.priority` first, strict FIFO within a class),
each engine step spends a TOKEN BUDGET that mixes one decode token per
decoding slot with chunked-prefill window tokens (Sarathi/vLLM-style
interleaving — a long prompt no longer monopolizes whole steps), and a
blocked higher-priority request may PREEMPT a lower-priority decoding
victim.  Requests move through:

  QUEUED -> PREFILLING -> DECODING -> FINISHED(finish_reason)
                 ^             |
                 |             v
                 + <------ PREEMPTED   (requeued at original arrival
                                        order within its class)

The Scheduler owns the queue, the slot array and the victim choice;
the engine owns the jitted compute and the per-backend eviction /
restore mechanics (snapshot, state-page keep, drop-and-recompute).
finish_reason is "stop" (eos or a SamplingParams stop token) or
"length" (max_new_tokens exhausted).

Observability (docs/observability.md): every StepOutput carries an
emission timestamp `t` (tune.timer.now monotonic seconds) and
`Scheduler.release` stamps + propagates the finish_reason onto the
request, so per-request latency is derivable post-hoc from the outputs
alone — no engine private state.  An optional Tracer (repro.obs)
additionally receives queued / admitted / blocked / preempted /
resumed events; when none is installed every hook site is a single
`is not None` check.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Iterator, List, Optional, Tuple

from repro.tune import timer


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class StepOutput:
    """One emitted token (or state transition) of one request."""

    rid: int
    token: Optional[int]
    state: RequestState
    finished: bool = False
    finish_reason: Optional[str] = None  # "stop" | "length"
    # emission timestamp (tune.timer.now seconds); finish outputs carry
    # the scheduler's release stamp, so ttft / inter-token / e2e spans
    # are recoverable from the StepOutput stream alone
    t: float = dataclasses.field(default_factory=timer.now)


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Resolves how many concurrent slots a (cfg, max_len) engine runs."""

    def resolve_slots(self, cfg, max_len: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedSlots(AdmissionPolicy):
    """Admit up to a fixed number of concurrent sequences."""

    slots: int = 4

    def resolve_slots(self, cfg, max_len: int) -> int:
        if self.slots < 1:
            raise ValueError(f"FixedSlots needs >= 1 slot, got {self.slots}")
        return self.slots


@dataclasses.dataclass(frozen=True)
class ByteBudget(AdmissionPolicy):
    """Admit while the decode-cache cost fits an HBM byte budget.

    Slot cost is the exact marginal decode-cache bytes of one sequence
    (serve.cache.per_slot_bytes eval_shapes the backend's own
    init_cache through the model), so the same budget admits far more
    O(D^2)-state linear/mamba2 sequences than O(S)-KV softmax ones —
    the paper's memory story, turned into admission control.
    """

    budget_bytes: int
    max_slots: int = 256  # compile-size guard, not a memory limit

    def resolve_slots(self, cfg, max_len: int) -> int:
        from repro.serve.cache import per_slot_bytes
        per = per_slot_bytes(cfg, max_len)
        n = min(self.max_slots, self.budget_bytes // per)
        if n < 1:
            raise ValueError(
                f"byte budget {self.budget_bytes} cannot admit even one "
                f"sequence: one slot's decode cache at max_len={max_len} "
                f"is {per} bytes (backend-resolved from cfg)")
        return int(n)


# ---------------------------------------------------------------------------
# Per-step token budget
# ---------------------------------------------------------------------------

class TokenBudget:
    """One engine step's token ledger (Sarathi-style mixing).

    The engine spends it decode-first (one token per decoding slot, the
    latency-critical work), then on prefill-window tokens until the
    next window no longer fits.  `force` lets the engine guarantee
    forward progress: when a step did nothing else, one window runs
    even if it overflows the budget (a budget smaller than the chunk
    size must not livelock prefill)."""

    def __init__(self, total: int):
        self.total = int(total)
        self.decode_tokens = 0
        self.prefill_tokens = 0

    @property
    def spent(self) -> int:
        return self.decode_tokens + self.prefill_tokens

    @property
    def remaining(self) -> int:
        return max(self.total - self.spent, 0)

    def fits(self, n: int) -> bool:
        return n <= self.remaining

    def spend_decode(self, n: int) -> None:
        self.decode_tokens += n

    def spend_prefill(self, n: int) -> None:
        self.prefill_tokens += n


# ---------------------------------------------------------------------------
# Priority scheduler with preemption (v2)
# ---------------------------------------------------------------------------

class Scheduler:
    """Priority admission over a fixed slot array.

    Holds no jax state: slots map indices into the engine's batched
    cache.  The queue drains highest-priority-first; WITHIN a priority
    class it is strictly FIFO by arrival (`Request.priority` defaults
    to 0, so a priority-free workload behaves exactly like the v1 FIFO
    scheduler).  A preempted request re-enters the queue under its
    ORIGINAL arrival order, so it resumes ahead of later arrivals of
    its own class.
    """

    def __init__(self, num_slots: int, tracer=None):
        self.num_slots = num_slots
        # heap of (-priority, arrival_seq, request)
        self.queue: List[tuple] = []
        self.slots: List[Optional[object]] = [None] * num_slots
        self.tracer = tracer   # repro.obs.Tracer hooks, or None
        self._seq = 0          # arrival order, assigned once per request
        self._seq_of: dict = {}        # rid -> arrival seq
        self._admit_seq = 0            # admission recency (victim tie-break)
        self._admitted_at: dict = {}   # rid -> admission seq

    def _push(self, req) -> None:
        prio = getattr(req, "priority", 0)
        heapq.heappush(self.queue, (-prio, self._seq_of[req.rid], req))

    def submit(self, req) -> None:
        req.state = RequestState.QUEUED
        self._seq_of[req.rid] = self._seq
        self._seq += 1
        self._push(req)
        if self.tracer is not None:
            self.tracer.request_queued(req.rid)

    def requeue(self, req) -> None:
        """Re-enter a preempted request under its original arrival seq
        (ahead of anything submitted after it in its priority class)."""
        req.state = RequestState.PREEMPTED
        self._push(req)

    def peek(self):
        """The next request admission would try, or None."""
        return self.queue[0][2] if self.queue else None

    def queued(self) -> Iterator[object]:
        """Waiting requests in admission order (heap order, exact)."""
        return (entry[2] for entry in sorted(self.queue))

    def admit(self, can_admit=None) -> List[Tuple[int, object]]:
        """Fill free slots from the queue head; returns [(slot, request)].

        `can_admit(req) -> bool` gates each admission beyond slot
        availability (the paged engine passes a free-page check).  A
        True verdict is ALWAYS followed by admission of that request,
        so the callback may reserve resources as its answer.  The
        queue never skips: when the HEAD request (highest priority,
        earliest arrival) doesn't fit, admission stops rather than
        admitting a later request past it, so a large request can't be
        starved by a stream of small ones."""
        admitted = []
        blocked = None   # why the queue head is still waiting, if it is
        for i, occupant in enumerate(self.slots):
            if occupant is None and self.queue:
                head = self.queue[0][2]
                if can_admit is not None and not can_admit(head):
                    blocked = "resources"
                    break
                heapq.heappop(self.queue)
                self.slots[i] = head
                self._admitted_at[head.rid] = self._admit_seq
                self._admit_seq += 1
                admitted.append((i, head))
                if self.tracer is not None:
                    self.tracer.request_admitted(head.rid, i)
        if blocked is None and self.queue:
            blocked = "slots"
        if blocked is not None and self.tracer is not None:
            self.tracer.admission_blocked(self.queue[0][2].rid, blocked)
        return admitted

    def pick_victim(self, min_priority: int) -> Optional[int]:
        """Slot of the best preemption victim for a blocked request of
        `min_priority`: a DECODING occupant of strictly lower priority
        — lowest priority first, most-recently-admitted on ties (the
        newest work loses the least progress).  None if no slot holds
        an eligible victim (PREFILLING slots are never preempted: their
        partial window state is not restorable)."""
        best = None
        for i, req in enumerate(self.slots):
            if req is None or req.state is not RequestState.DECODING:
                continue
            prio = getattr(req, "priority", 0)
            if prio >= min_priority:
                continue
            key = (prio, -self._admitted_at.get(req.rid, 0))
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    def preempt(self, slot: int) -> object:
        """Evict the slot's occupant back into the queue (PREEMPTED,
        original arrival order).  The engine performs the state
        eviction (snapshot / page policy) around this call."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is empty; nothing to preempt")
        self.slots[slot] = None
        self.requeue(req)
        return req

    def release(self, slot: int, finish_reason: Optional[str] = None
                ) -> float:
        """Free the slot; stamps and returns the finish timestamp and
        propagates `finish_reason` onto the occupant, so lifecycle
        timing + outcome survive the release (obs derives records
        without reaching into engine private state)."""
        t = timer.now()
        req = self.slots[slot]
        if req is not None and finish_reason is not None:
            req.finish_reason = finish_reason
        self.slots[slot] = None
        return t

    def active(self) -> Iterator[Tuple[int, object]]:
        return ((i, r) for i, r in enumerate(self.slots) if r is not None)

    def decoding(self) -> Iterator[Tuple[int, object]]:
        """Slots whose occupant is past prefill (consumes decode
        budget; their sampled batch token is surfaced)."""
        return ((i, r) for i, r in enumerate(self.slots)
                if r is not None and r.state is RequestState.DECODING)

    def prefilling(self) -> List[Tuple[int, object]]:
        """Slots mid-prefill, in (priority desc, admission order) —
        the order the engine feeds them prefill-window budget."""
        rows = [(i, r) for i, r in enumerate(self.slots)
                if r is not None and r.state is RequestState.PREFILLING]
        rows.sort(key=lambda ir: (-getattr(ir[1], "priority", 0),
                                  self._admitted_at.get(ir[1].rid, 0)))
        return rows

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
