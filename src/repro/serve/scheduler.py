"""Request lifecycle + admission control for the serving engine.

The paper's serving story is that the linear backend decodes from an
O(D^2) recurrent state while the softmax baseline drags an O(S) KV
cache; a fixed `max_slots` admission ignores that difference entirely.
Admission is therefore a pluggable policy that resolves the slot count
from the model config:

  FixedSlots(n)   the classic continuous-batching engine: n slots.
  ByteBudget(b)   admit while the per-slot decode-cache cost (exact,
                  from serve/cache.py's eval_shape accounting) fits an
                  HBM byte budget — the budget resolves PER BACKEND
                  automatically, so at the same budget the linear /
                  mamba2 backends run orders of magnitude more
                  concurrent sequences than softmax.
  PagedAdmission  (serve/paging.py) softmax + cfg.paging: the budget
                  buys an arena of fixed-size KV pages and requests are
                  admitted by the pages they ACTUALLY need, not a
                  worst-case max_len charge — long contexts stay
                  admissible until the arena is truly full.

Requests move through a lifecycle the engine surfaces per step:

  QUEUED -> PREFILLING -> DECODING -> FINISHED(finish_reason)

The Scheduler owns the FIFO queue and the slot array; the engine owns
the jitted compute.  finish_reason is "stop" (eos or a SamplingParams
stop token) or "length" (max_new_tokens exhausted).

Observability (docs/observability.md): every StepOutput carries an
emission timestamp `t` (tune.timer.now monotonic seconds) and
`Scheduler.release` stamps + propagates the finish_reason onto the
request, so per-request latency is derivable post-hoc from the outputs
alone — no engine private state.  An optional Tracer (repro.obs)
additionally receives queued / admitted / blocked events; when none is
installed every hook site is a single `is not None` check.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Iterator, List, Optional, Tuple

from repro.tune import timer


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


@dataclasses.dataclass
class StepOutput:
    """One emitted token (or state transition) of one request."""

    rid: int
    token: Optional[int]
    state: RequestState
    finished: bool = False
    finish_reason: Optional[str] = None  # "stop" | "length"
    # emission timestamp (tune.timer.now seconds); finish outputs carry
    # the scheduler's release stamp, so ttft / inter-token / e2e spans
    # are recoverable from the StepOutput stream alone
    t: float = dataclasses.field(default_factory=timer.now)


# ---------------------------------------------------------------------------
# Admission policies
# ---------------------------------------------------------------------------

class AdmissionPolicy:
    """Resolves how many concurrent slots a (cfg, max_len) engine runs."""

    def resolve_slots(self, cfg, max_len: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedSlots(AdmissionPolicy):
    """Admit up to a fixed number of concurrent sequences."""

    slots: int = 4

    def resolve_slots(self, cfg, max_len: int) -> int:
        if self.slots < 1:
            raise ValueError(f"FixedSlots needs >= 1 slot, got {self.slots}")
        return self.slots


@dataclasses.dataclass(frozen=True)
class ByteBudget(AdmissionPolicy):
    """Admit while the decode-cache cost fits an HBM byte budget.

    Slot cost is the exact marginal decode-cache bytes of one sequence
    (serve.cache.per_slot_bytes eval_shapes the backend's own
    init_cache through the model), so the same budget admits far more
    O(D^2)-state linear/mamba2 sequences than O(S)-KV softmax ones —
    the paper's memory story, turned into admission control.
    """

    budget_bytes: int
    max_slots: int = 256  # compile-size guard, not a memory limit

    def resolve_slots(self, cfg, max_len: int) -> int:
        from repro.serve.cache import per_slot_bytes
        per = per_slot_bytes(cfg, max_len)
        n = min(self.max_slots, self.budget_bytes // per)
        if n < 1:
            raise ValueError(
                f"byte budget {self.budget_bytes} cannot admit even one "
                f"sequence: one slot's decode cache at max_len={max_len} "
                f"is {per} bytes (backend-resolved from cfg)")
        return int(n)


# ---------------------------------------------------------------------------
# FIFO scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    """FIFO admission over a fixed slot array.

    Holds no jax state: slots map indices into the engine's batched
    cache; the queue drains strictly in submission order as slots free.
    """

    def __init__(self, num_slots: int, tracer=None):
        self.num_slots = num_slots
        self.queue: deque = deque()
        self.slots: List[Optional[object]] = [None] * num_slots
        self.tracer = tracer   # repro.obs.Tracer hooks, or None

    def submit(self, req) -> None:
        req.state = RequestState.QUEUED
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.request_queued(req.rid)

    def admit(self, can_admit=None) -> List[Tuple[int, object]]:
        """Fill free slots from the queue head; returns [(slot, request)].

        `can_admit(req) -> bool` gates each admission beyond slot
        availability (the paged engine passes a free-page check).  A
        True verdict is ALWAYS followed by admission of that request,
        so the callback may reserve resources as its answer.  The
        queue stays strictly FIFO: when the HEAD request doesn't fit,
        admission stops rather than skipping ahead, so a large request
        can't be starved by a stream of small ones."""
        admitted = []
        blocked = None   # why the queue head is still waiting, if it is
        for i, occupant in enumerate(self.slots):
            if occupant is None and self.queue:
                if can_admit is not None and not can_admit(self.queue[0]):
                    blocked = "resources"
                    break
                req = self.queue.popleft()
                self.slots[i] = req
                admitted.append((i, req))
                if self.tracer is not None:
                    self.tracer.request_admitted(req.rid, i)
        if blocked is None and self.queue:
            blocked = "slots"
        if blocked is not None and self.tracer is not None:
            self.tracer.admission_blocked(self.queue[0].rid, blocked)
        return admitted

    def release(self, slot: int, finish_reason: Optional[str] = None
                ) -> float:
        """Free the slot; stamps and returns the finish timestamp and
        propagates `finish_reason` onto the occupant, so lifecycle
        timing + outcome survive the release (obs derives records
        without reaching into engine private state)."""
        t = timer.now()
        req = self.slots[slot]
        if req is not None and finish_reason is not None:
            req.finish_reason = finish_reason
        self.slots[slot] = None
        return t

    def active(self) -> Iterator[Tuple[int, object]]:
        return ((i, r) for i, r in enumerate(self.slots) if r is not None)

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
