"""Paged-KV block pool: page manager + page-based admission.

The contiguous serving cache reserves batch x max_len KV rows up front,
so admission must charge every request the worst case and long contexts
become inadmissible long before HBM is actually full.  This module is
the vLLM-style alternative (docs/paged_kv.md):

  PagePool        host-side manager of a fixed arena of KV pages:
                  free-list allocation, per-request page tables,
                  ref-counted pages with copy-on-write forking so
                  identical prompt prefixes share pages.  The pool owns
                  BOOKKEEPING only — the arrays live in the engine's
                  cache (mixers.cache.PagedKVCache); CoW page copies are
                  returned as (src, dst) pairs for the engine to apply.
  PagedAdmission  resolves an HBM byte budget into arena pages and lets
                  the engine admit by pages a request ACTUALLY needs
                  (ceil(tokens / page_size)) instead of worst-case
                  max_len bytes per slot — a long-context request that
                  ByteBudget would refuse fits as long as its tokens do.

Preemption (scheduler v2, docs/serving.md): `free(rid)` is NOT tied to
request finish — the engine also calls it to evict a preemption
victim's pages mid-flight, and the victim re-admits later through a
fresh `allocate_pages` (drop-and-recompute) or, for the gla paged
STATE layout, keeps its one page across the preemption entirely:
`holds(rid)` lets admission recognize that standing reservation
instead of double-allocating.

The pool is deliberately jax-free: it runs on the host between engine
steps, like the Scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.scheduler import AdmissionPolicy


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


def pages_for(num_tokens: int, page_size: int) -> int:
    """Pages needed to hold `num_tokens` KV entries."""
    return -(-max(num_tokens, 0) // page_size)


class PagePool:
    """Fixed arena of `num_pages` KV pages, allocated from a free list.

    Pages are ref-counted: `fork` shares a prefix's FULL pages between
    two requests (copy-on-write — the partial tail page is copied, so a
    writable frontier is never shared) and `free` returns a page to the
    free list only when its last owner drops it.  The free list is LIFO:
    recently-freed pages are reused first, keeping the hot arena
    footprint small.
    """

    def __init__(self, num_pages: int, page_size: int, tracer=None):
        if num_pages < 1 or page_size < 1:
            raise ValueError(
                f"PagePool needs num_pages >= 1 and page_size >= 1, got "
                f"{num_pages} / {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.tracer = tracer   # repro.obs.Tracer hooks, or None
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._refcount = [0] * num_pages
        self._tables: Dict[int, List[int]] = {}   # rid -> page ids

    def _notify(self) -> None:
        """Mirror the pool level into the tracer's pages gauges after
        any allocation / free (docs/observability.md)."""
        if self.tracer is not None:
            self.tracer.pages_changed(self.pages_in_use, self.free_pages)

    # -- introspection -------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._refcount[page]

    def table(self, rid: int) -> List[int]:
        """The request's page ids, in token order (a copy)."""
        return list(self._tables[rid])

    def holds(self, rid: int) -> bool:
        """Whether the request currently holds an allocation — True for
        a preempted gla request that kept its state page, so re-
        admission swaps the page back in instead of allocating anew."""
        return rid in self._tables

    def pages_needed(self, num_tokens: int) -> int:
        return pages_for(num_tokens, self.page_size)

    def can_allocate(self, num_tokens: int) -> bool:
        return self.pages_needed(num_tokens) <= len(self._free)

    # -- lifecycle -----------------------------------------------------
    def _take(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages but only {len(self._free)} of "
                f"{self.num_pages} are free")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refcount[p] = 1
        self._notify()
        return pages

    def allocate(self, rid: int, num_tokens: int) -> List[int]:
        """Allocate pages for a new request covering `num_tokens`."""
        return self.allocate_pages(rid, self.pages_needed(num_tokens))

    def allocate_pages(self, rid: int, n_pages: int) -> List[int]:
        """Allocate an explicit page COUNT (the GLA paged-state path:
        one state page per request, whatever its token count)."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already holds pages")
        pages = self._take(n_pages)
        self._tables[rid] = pages
        return pages

    def extend(self, rid: int, num_tokens: int) -> List[int]:
        """Grow a request's table to cover `num_tokens` total; returns
        the newly-allocated pages ([] if it already fits)."""
        table = self._tables[rid]
        need = self.pages_needed(num_tokens) - len(table)
        if need <= 0:
            return []
        new = self._take(need)
        table.extend(new)
        return new

    def free(self, rid: int) -> List[int]:
        """Drop the request's references; returns pages actually freed
        (refcount reached zero — shared prefix pages survive)."""
        freed = []
        for p in self._tables.pop(rid):
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        self._notify()
        return freed

    def fork(self, src_rid: int, dst_rid: int,
             shared_tokens: int) -> Tuple[List[int], List[Tuple[int, int]]]:
        """Copy-on-write fork: dst shares src's first `shared_tokens`
        tokens.  Full pages of the shared prefix are SHARED (refcount+1,
        zero copies); a partial tail page is backed by a fresh page and
        returned as a (src_page, dst_page) copy for the engine to apply
        to the arenas — the writable frontier is never aliased, so
        neither request can clobber the other's tokens.

        Returns (dst's page table so far, arena copies to perform).
        """
        if dst_rid in self._tables:
            raise ValueError(f"request {dst_rid} already holds pages")
        src = self._tables[src_rid]
        if shared_tokens > len(src) * self.page_size:
            raise ValueError(
                f"fork of {shared_tokens} tokens exceeds request "
                f"{src_rid}'s {len(src)} pages")
        full, rem = divmod(shared_tokens, self.page_size)
        shared = src[:full]
        for p in shared:
            self._refcount[p] += 1
        copies: List[Tuple[int, int]] = []
        table = list(shared)
        if rem:
            [tail] = self._take(1)
            copies.append((src[full], tail))
            table.append(tail)
        self._tables[dst_rid] = table
        if self.tracer is not None:
            self.tracer.cow_fork()
        self._notify()
        return table, copies


def num_pages_for_budget(cfg, budget_bytes: int, page_size: int) -> int:
    """Arena pages (total, incl. the engine's reserved sink page) that
    fit an HBM byte budget for this config.  `serve.cache.page_bytes`
    prices a page per backend: KV rows for softmax, one whole recurrent
    state for gla — so the same policy sizes both arena layouts."""
    from repro.serve.cache import page_bytes
    return budget_bytes // page_bytes(cfg, page_size)


@dataclasses.dataclass(frozen=True)
class PagedAdmission(AdmissionPolicy):
    """Admit by free PAGES instead of worst-case bytes.

    The byte budget buys `num_pages = budget // page_bytes(cfg)` arena
    pages (serve.cache.page_bytes: 2 * ps * Hkv * hd * itemsize across
    layers; one page is the engine's reserved write sink).  A request is
    admitted when ceil((prompt + max_new - 1) / page_size) pages are
    free — its ACTUAL footprint — so at the same budget a long-context
    request that ByteBudget's per-slot max_len charge would refuse is
    admissible as long as its tokens fit (docs/paged_kv.md has the
    math).  `max_slots` bounds the compiled batch, not memory.
    """

    budget_bytes: int
    page_size: int = 16
    max_slots: int = 4
    num_pages: Optional[int] = None   # override: skip the budget math

    def resolve_num_pages(self, cfg) -> int:
        n = self.num_pages if self.num_pages is not None else \
            num_pages_for_budget(cfg, self.budget_bytes, self.page_size)
        if n < 2:
            from repro.serve.cache import page_bytes
            raise ValueError(
                f"byte budget {self.budget_bytes} buys {n} page(s) of "
                f"{page_bytes(cfg, self.page_size)} bytes "
                f"(page_size={self.page_size}); the paged arena needs "
                f">= 2 (one allocatable + the reserved sink page)")
        return int(n)

    def resolve_slots(self, cfg, max_len: int) -> int:
        if self.max_slots < 1:
            raise ValueError(
                f"PagedAdmission needs >= 1 slot, got {self.max_slots}")
        self.resolve_num_pages(cfg)   # fail fast on impossible budgets
        return self.max_slots
