"""Batched serving engine with continuous batching.

Fixed-slot engine: up to `max_slots` concurrent sequences share one
jitted decode step; finished slots are immediately refilled from the
queue (continuous batching).  With the paper's linear backend every
slot's cache is the O(D^2) recurrent state, so slot memory does not
grow with generated length — admission control is trivial compared to
paged KV caches.

The engine is backend-agnostic: the mixer is resolved once through the
attention-backend registry (which validates the config and names the
registered backends on error), and all cache handling is pure pytree
scatter/gather batched on the leading batch dim — LAState, KVCache,
MambaCache and CrossState flow through the same code.  Slots decode at
PER-SLOT positions (cache["pos"] is per-sequence), which the softmax
backend's KV scatter/masking honors exactly.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.mixers import get_backend
from repro.models import model as mdl

F32 = jnp.float32


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list            # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0
    generated: Optional[list] = None


class Engine:
    def __init__(self, cfg, params, *, max_slots: int = 4,
                 max_len: int = 4096, eos_id: int = 2, seed: int = 0):
        self.cfg = cfg
        self.backend = get_backend(cfg)  # validates cfg at admission time
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.cache = mdl.init_cache(cfg, max_slots, max_len)
        self.next_tokens = np.zeros((max_slots,), np.int32)
        self.remaining = np.zeros((max_slots,), np.int64)
        self.key = jax.random.PRNGKey(seed)

        self._decode = jax.jit(
            lambda p, c, t: mdl.decode_step(p, cfg, c, t))
        # prefill uses batch 1 and is scattered into the slot
        self._prefill = jax.jit(
            lambda p, b, c: mdl.prefill(p, cfg, b, c))

    # -- public API ----------------------------------------------------
    def submit(self, req: Request):
        req.generated = []
        self.queue.append(req)

    def run(self) -> dict[int, list]:
        """Run until queue + slots drain.  Returns rid -> generated ids."""
        done: dict[int, list] = {}
        while self._admit() or any(s is not None for s in self.slots):
            self._step(done)
        return done

    # -- internals -------------------------------------------------------
    def _admit(self) -> bool:
        admitted = False
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(i, req)
                self.slots[i] = req
                admitted = True
        return admitted

    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        batch = {"tokens": toks}
        if self.cfg.rope_kind == "mrope":
            n = toks.shape[1]
            pos = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (1, n))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, 1, n))
        cache1 = mdl.init_cache(self.cfg, 1, self.max_len)
        logits, cache1 = self._prefill(self.params, batch, cache1)
        tok = self._sample(logits, req.temperature)
        # scatter slot-1 cache into the batched cache at index `slot`
        def put(big, small):
            if small.ndim == 0:
                return small  # pos counter: shared scalar (see note below)
            bdim = _batch_dim(big, small)
            if bdim is None:
                return big
            idx = [slice(None)] * big.ndim
            idx[bdim] = slot
            return big.at[tuple(idx)].set(jnp.take(small, 0, axis=bdim))
        self.cache = jax.tree.map(put, self.cache, cache1)
        self.next_tokens[slot] = int(tok[0])
        # the prefill already produced the first new token
        self.remaining[slot] = req.max_new_tokens - 1
        req.generated.append(int(tok[0]))

    def _sample(self, logits, temperature):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    def _step(self, done: dict):
        # finalize slots already exhausted (or EOS'd) at prefill time
        for i, req in enumerate(self.slots):
            if req is not None and (self.remaining[i] <= 0
                                    or self.next_tokens[i] == self.eos_id):
                done[req.rid] = req.generated
                self.slots[i] = None
        if all(s is None for s in self.slots):
            return
        toks = jnp.asarray(self.next_tokens)
        logits, self.cache = self._decode(self.params, self.cache, toks)
        nxt = np.array(self._sample(logits, 0.0))  # writable copy
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.generated.append(tok)
            self.remaining[i] -= 1
            if tok == self.eos_id or self.remaining[i] <= 0:
                done[req.rid] = req.generated
                self.slots[i] = None
        self.next_tokens = nxt


def _batch_dim(big, small):
    """First dim where big.shape[d] != small.shape[d] (the batch dim)."""
    for d in range(small.ndim):
        if big.shape[d] != small.shape[d]:
            return d
    return None
