"""Continuous-batching serving engine — the request-lifecycle API.

Layering (serving API v2):

  sampling.SamplingParams   per-request temperature / top-k / top-p /
                            stop tokens / seed, applied INSIDE the one
                            jitted decode step (greedy slots keep the
                            exact argmax path).
  scheduler.Scheduler       FIFO queue + slot array; admission policies
                            (FixedSlots, ByteBudget) resolve the slot
                            count — ByteBudget from the exact per-slot
                            decode-cache bytes, so the paper's O(D^2)
                            linear state admits orders of magnitude more
                            concurrent sequences than the softmax KV
                            cache at the same HBM budget.
  Engine                    owns the batched cache + jitted steps and
                            surfaces the lifecycle: step() advances one
                            engine iteration and returns StepOutputs;
                            stream() yields them; run() drains to a
                            rid -> tokens dict.

Prefill is CHUNKED and in-place: each prompt window runs through
`model.prefill` on the slot's own row of the batched cache (pytree
gather -> batch-1 prefill continuing from the slot's position -> pytree
scatter back), so admission allocates no throwaway max_len cache and a
long prompt compiles one window-sized prefill instead of one giant
prompt-length one.  Windowed prefill is exact for every backend: the
recurrent mixers carry their state, and the softmax baseline's windows
attend to the cached prefix (continuation prefill, mixers/softmax.py —
on the pallas kernel impls the per-slot offsets go through the flash
kernel's scalar-prefetch path, no XLA fallback).  `kernel_backend`
overrides cfg.la.backend at construction so a serving deployment can
pick the kernel impl (e.g. "pallas" on TPU) without rebuilding configs.

PAGED-KV mode (docs/paged_kv.md): a PagedAdmission policy — or explicit
page_size/num_pages kwargs — switches the softmax KV cache to a shared
arena of fixed-size pages (mixers.cache.PagedKVCache).  The engine owns
a host-side PagePool: admission is gated on the pages a request
actually needs, prefill windows write straight into its allocated
pages, decode runs the "paged" kernel family (Pallas page-table
gather), and finishing a request returns its pages to the free list.
The last arena page is reserved as a write sink so retired slots —
which keep decoding as batch padding — can never corrupt a live page.

OBSERVABILITY (docs/observability.md): `Engine(tracer=...)` installs a
repro.obs Tracer and the engine emits the request lifecycle as events —
submit/reject, queued, admitted (via the Scheduler), per-window prefill
spans, per-token decode ticks, finish — plus a per-step span with
occupancy/queue gauges; the PagePool mirrors its level into pages
gauges.  Hooks are host-side only and gated on `tracer is not None`,
so the default engine runs zero instrumentation and traced output is
token-identical to untraced (pinned by tests/test_obs.py).  The only
behavioral difference under tracing is a block_until_ready per prefill
window so window spans measure device time, not dispatch time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PagingCfg
from repro.mixers import get_backend, resolve_backend_name
from repro.mixers.cache import PagedGLAState, PagedKVCache
from repro.models import model as mdl
from repro.serve import sampling as smp
from repro.serve.paging import PagedAdmission, PagePool
from repro.serve.scheduler import AdmissionPolicy, ByteBudget, \
    FixedSlots, RequestState, Scheduler, StepOutput
from repro.tune import timer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                     # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0         # shorthand; `sampling` wins if set
    sampling: Optional[smp.SamplingParams] = None
    generated: Optional[list] = None
    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[str] = None
    finish_t: Optional[float] = None   # Scheduler.release stamp (timer.now)

    def resolved_sampling(self) -> smp.SamplingParams:
        return self.sampling or smp.SamplingParams(
            temperature=self.temperature)


# ---------------------------------------------------------------------------
# Batched-cache slot addressing
# ---------------------------------------------------------------------------

def _cache_batch_dims(cfg, slots: int, max_len: int):
    """Per-leaf batch-dim pytree, found by growing the slot count by one
    under eval_shape (layer-stacked leaves carry their batch dim at
    different positions; -1 marks leaves that don't scale with slots)."""
    a = jax.eval_shape(lambda: mdl.init_cache(cfg, slots, max_len))
    b = jax.eval_shape(lambda: mdl.init_cache(cfg, slots + 1, max_len))

    def dim(sa, sb):
        for d, (x, y) in enumerate(zip(sa.shape, sb.shape)):
            if x != y:
                return d
        return -1

    return jax.tree.map(dim, a, b)


def _gather_slot(cache, bdims, slot):
    """Batch-1 view of one slot's rows (slot may be a traced scalar)."""
    return jax.tree.map(
        lambda x, d: x if d < 0
        else jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=d),
        cache, bdims)


def _scatter_slot(cache, small, bdims, slot):
    """Write a batch-1 cache back into the slot's rows.  Leaves with no
    batch dim (the paged-KV arenas, shared across slots) pass through
    from `small`: prefill writes the slot's pages in place, so the
    updated arena IS the new cache leaf."""
    return jax.tree.map(
        lambda big, s, d: s.astype(big.dtype) if d < 0
        else jax.lax.dynamic_update_slice_in_dim(
            big, s.astype(big.dtype), slot, axis=d),
        cache, small, bdims)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    def __init__(self, cfg, params, *, max_slots: int = 4,
                 max_len: int = 4096, eos_id: int = 2, seed: int = 0,
                 policy: Optional[AdmissionPolicy] = None,
                 prefill_chunk: Optional[int] = None,
                 kernel_backend: Optional[str] = None,
                 fused_decode: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 tracer=None):
        # repro.obs Tracer (or None = zero instrumentation); set first
        # so the Scheduler and PagePool constructed below share it
        self.tracer = tracer
        if cfg.family == "encdec":
            raise NotImplementedError(
                "the serving engine targets decoder-only families; "
                "whisper decode needs per-request encoder frames")
        if kernel_backend is not None:
            # deployment knob: pick the kernel impl (xla / pallas / ...)
            # for this engine; get_backend below re-validates the name
            cfg = dataclasses.replace(
                cfg, la=dataclasses.replace(cfg.la,
                                            backend=kernel_backend))
        if fused_decode is not None:
            # deployment knob: route decode through the fused
            # single-kernel step families (docs/fused_decode.md) or pin
            # the legacy unfused composition — parity is tested via
            # tests/helpers.assert_engine_identity
            cfg = dataclasses.replace(
                cfg, la=dataclasses.replace(cfg.la,
                                            fused_decode=fused_decode))
        self.policy = policy if policy is not None else FixedSlots(max_slots)
        # paged-KV mode: PagedAdmission implies it (arena sized from the
        # byte budget); --page-size/--num-pages request it explicitly.
        # The LAST arena page is reserved as a write sink: retired slots
        # keep decoding as batch padding, and their table rows point at
        # it so those writes can never corrupt a live request's pages.
        if isinstance(self.policy, PagedAdmission):
            if page_size is not None or num_pages is not None:
                raise ValueError(
                    "PagedAdmission already fixes page_size/num_pages "
                    "from its byte budget; drop the engine kwargs")
            page_size = self.policy.page_size
            num_pages = self.policy.resolve_num_pages(cfg)
        elif page_size is not None and isinstance(self.policy, ByteBudget):
            # ByteBudget's per-slot charge collapses to the int32
            # page-table row once cfg.paging is set (the arena has no
            # batch dim), so it would resolve a nonsense slot count —
            # the page-aware byte policy IS PagedAdmission
            raise ValueError(
                "ByteBudget admission cannot size a paged engine; use "
                "PagedAdmission(budget_bytes, page_size=...) instead")
        if num_pages is not None and page_size is None:
            raise ValueError(
                "num_pages without page_size: set page_size to enable "
                "the paged-KV cache")
        if page_size is not None:
            # gla pages hold one slot's recurrent STATE each; softmax
            # pages hold page_size KV rows (docs/paged_kv.md)
            state_paged = resolve_backend_name(cfg) == "gla"
            pages_per_seq = 1 if state_paged \
                else -(-max_len // page_size)
            if num_pages is None:
                # default arena: worst case for every slot, plus sink —
                # same HBM as contiguous, still page-granular admission
                n_slots = self.policy.resolve_slots(cfg, max_len)
                num_pages = n_slots * pages_per_seq + 1
            cfg = dataclasses.replace(
                cfg, paging=PagingCfg(page_size=page_size,
                                      num_pages=num_pages))
        self.cfg = cfg
        self.backend = get_backend(cfg)  # validates cfg at admission time
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.seed = seed
        self.prefill_chunk = prefill_chunk
        self.num_slots = self.policy.resolve_slots(cfg, max_len)
        self.max_slots = self.num_slots  # engine-v1 attribute, kept
        self.scheduler = Scheduler(self.num_slots, tracer=tracer)

        n = self.num_slots
        self.cache = mdl.init_cache(cfg, n, max_len)
        self._bdims = _cache_batch_dims(cfg, n, max_len)
        self.pool: Optional[PagePool] = None
        self._state_paged = False
        if cfg.paging is not None:
            # dense-prefix (MoE first_dense_layers) caches carry extra
            # per-layer paged caches under "prefix" whose page tables
            # the engine does not manage — reject rather than serve
            # silently-wrong prefix attention
            blocks = self.cache.get("blocks")
            if not isinstance(blocks, (PagedKVCache, PagedGLAState)) \
                    or "prefix" in self.cache:
                raise NotImplementedError(
                    "paged serving needs the plain decoder cache "
                    "layout (softmax or gla attention backend, no "
                    "dense-prefix layers)")
            self._state_paged = isinstance(blocks, PagedGLAState)
            self._zero_pages = None   # donated page-wipe jit, built lazily
            self._sink_page = cfg.paging.num_pages - 1
            self._pages_per_seq = blocks.page_table.shape[-1]
            # model.init_cache stacks layers with zeros_like, which
            # wipes the mixer's sink-page fill — re-point EVERY row at
            # the sink so slots that were never admitted pad their
            # decode writes there, not into arena page 0
            self.cache["blocks"] = blocks._replace(
                page_table=jnp.full_like(blocks.page_table,
                                         self._sink_page))
            self.pool = PagePool(cfg.paging.num_pages - 1,
                                 cfg.paging.page_size, tracer=tracer)
        self.next_tokens = np.zeros((n,), np.int32)
        self.remaining = np.zeros((n,), np.int64)
        # per-slot sampling state, mirrored into the jitted decode step
        self._temp = np.zeros((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._topp = np.ones((n,), np.float32)
        self._keys = np.zeros((n, 2), np.uint32)
        self._params_of: List[Optional[smp.SamplingParams]] = [None] * n
        self._requests: Dict[int, Request] = {}

        def decode_fn(params, cache, tokens, keys, temp, topk, topp):
            logits, cache = mdl.decode_step(params, cfg, cache, tokens)
            toks, keys = smp.sample(logits, keys, temp, topk, topp)
            return toks, cache, keys

        # the cache is DONATED: XLA updates the KV / state arenas in
        # place instead of copying them every token (_decode_once
        # immediately rebinds self.cache from the result, so the stale
        # buffer is never touched).  analysis/hlo.py's
        # assert_cache_donation pins that the aliasing survives
        # compilation (tests/test_decode_fused.py).
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._sample1 = jax.jit(smp.sample)   # prefill's first token
        self._prefill_fns: dict = {}          # (window_len, fresh) -> jit

    # -- public API ----------------------------------------------------
    def request(self, rid: int) -> Request:
        """The submitted Request (its generated tokens, state and
        finish_reason update in place as the engine advances)."""
        return self._requests[rid]

    def submit(self, req: Request):
        if self.tracer is not None:
            self.tracer.request_submitted(req.rid, len(req.prompt),
                                          req.max_new_tokens)
        # cache positions written: len(prompt) prefill + max_new-1 decode
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.max_len:
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "max_len")
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)} tokens) + "
                f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
                f"positions but the engine was built with max_len="
                f"{self.max_len}")
        if self.pool is not None \
                and self._req_pages(req) > self.pool.num_pages:
            # would never admit: the FIFO queue would deadlock behind it
            kind = "state" if self._state_paged else "KV"
            detail = "a page holds one slot's whole recurrent state" \
                if self._state_paged \
                else f"page_size={self.pool.page_size}"
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "arena")
            raise ValueError(
                f"request {req.rid} needs {self._req_pages(req)} "
                f"{kind} pages but the whole arena has "
                f"{self.pool.num_pages} allocatable pages ({detail})")
        if req.generated is None:
            req.generated = []
        self._requests[req.rid] = req
        self.scheduler.submit(req)

    def step(self) -> List[StepOutput]:
        """Advance one engine iteration: admit + prefill queued requests
        into free slots, then decode one token for every decoding slot.
        Returns the StepOutputs emitted by this iteration."""
        tr = self.tracer
        t0 = timer.now() if tr is not None else 0.0
        outputs: List[StepOutput] = []
        for slot, req in self.scheduler.admit(self._can_admit):
            outputs.append(self._admit_into(slot, req))
        outputs.extend(self._decode_once())
        if tr is not None:
            active = sum(1 for _ in self.scheduler.active())
            tr.engine_step(t0, active, self.num_slots,
                           len(self.scheduler.queue))
        return outputs

    def stream(self) -> Iterator[StepOutput]:
        """Yield StepOutputs until queue and slots drain."""
        while self.scheduler.has_work():
            yield from self.step()

    def run(self) -> Dict[int, list]:
        """Run until queue + slots drain.  Returns rid -> generated ids."""
        done: Dict[int, list] = {}
        for out in self.stream():
            if out.finished:
                done[out.rid] = self._requests[out.rid].generated
        return done

    # -- admission + chunked prefill -----------------------------------
    def _can_admit(self, req) -> bool:
        """Beyond a free slot, a paged engine needs the request's pages
        to be free RIGHT NOW (its worst-case token footprint — prompt
        plus every decode position it may write; ONE state page for the
        gla layout, whatever the token count).  The check RESERVES the
        pages: Scheduler.admit may probe several queued requests for
        one batch of free slots before the engine prefills any of them,
        so a pure lookahead would over-admit against the same free
        pages (a True verdict is always followed by admission, so a
        reservation never leaks)."""
        if self.pool is None:
            return True
        need = self._req_pages(req)
        if need > self.pool.free_pages:
            return False
        self.pool.allocate_pages(req.rid, need)
        return True

    def _req_pages(self, req) -> int:
        """Arena pages the request needs for its whole lifetime."""
        if self._state_paged:
            return 1   # one O(D^2) state page, independent of tokens
        return self.pool.pages_needed(self._token_footprint(req))

    @staticmethod
    def _token_footprint(req) -> int:
        # cache positions written: len(prompt) prefill + max_new-1 decode
        return len(req.prompt) + req.max_new_tokens - 1

    def _set_page_row(self, slot: int, pages: List[int]) -> None:
        """Point slot's page-table row (all layers) at `pages`, padding
        the unallocated tail with the reserved sink page.  State pages
        (gla) are also ZEROED on assignment: the recurrent state
        accumulates, so a freed request's stale state must not seed the
        next one's recurrence (KV pages need no wipe — attention masks
        by length and rows are overwritten before they are exposed)."""
        row = np.full((self._pages_per_seq,), self._sink_page, np.int32)
        row[:len(pages)] = pages
        blocks = self.cache["blocks"]
        if self._state_paged and pages:
            # donated jit so XLA scatters the zeros in place — a bare
            # .at[].set here would materialize a full copy of every
            # layer's state arena per admission
            if self._zero_pages is None:
                self._zero_pages = jax.jit(
                    lambda s, p, idx: (s.at[:, idx].set(0.0),
                                       p.at[:, idx].set(0.0)),
                    donate_argnums=(0, 1))
            s_z, p_z = self._zero_pages(blocks.s_pages, blocks.p_pages,
                                        jnp.asarray(pages, jnp.int32))
            blocks = blocks._replace(s_pages=s_z, p_pages=p_z)
        self.cache["blocks"] = blocks._replace(
            page_table=blocks.page_table.at[:, slot, :].set(
                jnp.asarray(row)))

    def _prefill_fn(self, n: int, fresh: bool):
        """Jitted: one n-token prompt window through the slot's own rows
        of the batched cache (gather -> prefill -> scatter).  `fresh`
        zeroes the slot's rows first (new admission over a stale slot);
        later windows continue from the carried position/state."""
        key = (n, fresh)
        if key not in self._prefill_fns:
            cfg, bdims = self.cfg, self._bdims
            paged = self.pool is not None

            def zero_fresh(small):
                if not paged:
                    return jax.tree.map(jnp.zeros_like, small)
                # paged: the arena and the just-assigned page-table row
                # must survive; stale page CONTENT needs no zeroing (it
                # is overwritten before the length mask exposes it)
                return {k: (v if k == "blocks"
                            else jax.tree.map(jnp.zeros_like, v))
                        for k, v in small.items()}

            def fn(params, cache, tokens, slot):
                small = _gather_slot(cache, bdims, slot)
                if fresh:
                    small = zero_fresh(small)
                batch = {"tokens": tokens}
                if cfg.rope_kind == "mrope":
                    start = small["rope_pos"]          # (1,)
                    pos = (start[:, None]
                           + jnp.arange(n, dtype=jnp.int32)[None])
                    batch["positions"] = jnp.broadcast_to(
                        pos[None], (3, 1, n))
                logits, small = mdl.prefill(params, cfg, batch, small)
                return logits, _scatter_slot(cache, small, bdims, slot)

            self._prefill_fns[key] = jax.jit(fn)
        return self._prefill_fns[key]

    def _windows(self, prompt: list) -> List[list]:
        w = self.prefill_chunk
        if w is None or len(prompt) <= w:
            return [prompt]
        return [prompt[i:i + w] for i in range(0, len(prompt), w)]

    def _admit_into(self, slot: int, req: Request) -> StepOutput:
        req.state = RequestState.PREFILLING
        if req.generated is None:
            req.generated = []
        if self.pool is not None:
            # pages were reserved by _can_admit at admission time
            self._set_page_row(slot, self.pool.table(req.rid))
        sp = req.resolved_sampling()
        self._params_of[slot] = sp
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        key = smp.request_key(sp, self.seed, req.rid)

        tr = self.tracer
        logits = None
        for i, window in enumerate(self._windows(req.prompt)):
            fn = self._prefill_fn(len(window), fresh=(i == 0))
            t0 = timer.now() if tr is not None else 0.0
            logits, self.cache = fn(
                self.params, self.cache,
                jnp.asarray(window, jnp.int32)[None],
                jnp.int32(slot))
            if tr is not None:
                # span measures device time; the sync changes no values
                jax.block_until_ready(logits)
                tr.prefill_window(req.rid, slot, len(window), t0)
        # the prefill already produced the first new token, sampled with
        # the request's own params + key (engine v1 greedy'd from here on)
        toks, key = self._sample1(
            logits, key[None],
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32))
        tok = int(toks[0])
        self._keys[slot] = np.array(key[0])
        self.next_tokens[slot] = tok
        self.remaining[slot] = req.max_new_tokens - 1
        req.generated.append(tok)
        if tr is not None:
            tr.token_emitted(req.rid, slot)
        req.state = RequestState.DECODING
        reason = self._finish_reason(slot, tok, sp)
        if reason:
            return self._finish(slot, req, tok, reason)
        return StepOutput(req.rid, tok, req.state)

    # -- decode --------------------------------------------------------
    def _decode_once(self) -> List[StepOutput]:
        active = list(self.scheduler.active())
        if not active:
            return []
        toks, self.cache, keys = self._decode(
            self.params, self.cache,
            jnp.asarray(self.next_tokens),
            jnp.asarray(self._keys),
            jnp.asarray(self._temp),
            jnp.asarray(self._topk),
            jnp.asarray(self._topp))
        nxt = np.asarray(toks)
        self._keys = np.array(keys)  # writable copy
        tr = self.tracer
        outputs = []
        for slot, req in active:
            tok = int(nxt[slot])
            req.generated.append(tok)
            if tr is not None:
                tr.token_emitted(req.rid, slot)
            self.next_tokens[slot] = tok
            self.remaining[slot] -= 1
            reason = self._finish_reason(slot, tok, self._params_of[slot])
            if reason:
                outputs.append(self._finish(slot, req, tok, reason))
            else:
                outputs.append(StepOutput(req.rid, tok, req.state))
        return outputs

    # -- lifecycle -----------------------------------------------------
    def _finish_reason(self, slot: int, tok: int,
                       sp: smp.SamplingParams) -> Optional[str]:
        if tok == self.eos_id or tok in sp.stop:
            return "stop"
        if self.remaining[slot] <= 0:
            return "length"
        return None

    def _finish(self, slot: int, req: Request, tok: int,
                reason: str) -> StepOutput:
        req.state = RequestState.FINISHED
        t_fin = self.scheduler.release(slot, finish_reason=reason)
        req.finish_t = t_fin
        if self.pool is not None:
            # return the pages and re-point the slot at the sink page:
            # the retired slot keeps decoding as batch padding, and its
            # writes must not land in pages the free list may re-issue
            self.pool.free(req.rid)
            self._set_page_row(slot, [])
            if self.tracer is not None:
                self.tracer.sink_repoint()
        if self.tracer is not None:
            self.tracer.request_finished(req.rid, reason, t_fin)
        self._params_of[slot] = None
        self._temp[slot] = 0.0  # freed slots decode greedily (masked out)
        return StepOutput(req.rid, tok, req.state, finished=True,
                          finish_reason=reason, t=t_fin)

    # -- paged-KV stats (benchmarks / launcher artifacts) --------------
    def page_stats(self) -> Optional[Dict[str, int]]:
        """None unless paged; else allocatable / free / in-use pages."""
        if self.pool is None:
            return None
        return {"page_size": self.pool.page_size,
                "num_pages": self.pool.num_pages,
                "free_pages": self.pool.free_pages,
                "pages_in_use": self.pool.pages_in_use}
