"""Continuous-batching serving engine — the request-lifecycle API.

Layering (serving API v2):

  sampling.SamplingParams   per-request temperature / top-k / top-p /
                            stop tokens / seed, applied INSIDE the one
                            jitted decode step (greedy slots keep the
                            exact argmax path).
  scheduler.Scheduler       FIFO queue + slot array; admission policies
                            (FixedSlots, ByteBudget) resolve the slot
                            count — ByteBudget from the exact per-slot
                            decode-cache bytes, so the paper's O(D^2)
                            linear state admits orders of magnitude more
                            concurrent sequences than the softmax KV
                            cache at the same HBM budget.
  Engine                    owns the batched cache + jitted steps and
                            surfaces the lifecycle: step() advances one
                            engine iteration and returns StepOutputs;
                            stream() yields them; run() drains to a
                            rid -> tokens dict.

Prefill is CHUNKED and in-place: each prompt window runs through
`model.prefill` on the slot's own row of the batched cache (pytree
gather -> batch-1 prefill continuing from the slot's position -> pytree
scatter back), so admission allocates no throwaway max_len cache and a
long prompt compiles one window-sized prefill instead of one giant
prompt-length one.  Windowed prefill is exact for every backend: the
recurrent mixers carry their state, and the softmax baseline's windows
attend to the cached prefix (continuation prefill, mixers/softmax.py —
on the pallas kernel impls the per-slot offsets go through the flash
kernel's scalar-prefetch path, no XLA fallback).  `kernel_backend`
overrides cfg.la.backend at construction so a serving deployment can
pick the kernel impl (e.g. "pallas" on TPU) without rebuilding configs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.mixers import get_backend
from repro.models import model as mdl
from repro.serve import sampling as smp
from repro.serve.scheduler import AdmissionPolicy, FixedSlots, \
    RequestState, Scheduler, StepOutput


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                     # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0         # shorthand; `sampling` wins if set
    sampling: Optional[smp.SamplingParams] = None
    generated: Optional[list] = None
    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[str] = None

    def resolved_sampling(self) -> smp.SamplingParams:
        return self.sampling or smp.SamplingParams(
            temperature=self.temperature)


# ---------------------------------------------------------------------------
# Batched-cache slot addressing
# ---------------------------------------------------------------------------

def _cache_batch_dims(cfg, slots: int, max_len: int):
    """Per-leaf batch-dim pytree, found by growing the slot count by one
    under eval_shape (layer-stacked leaves carry their batch dim at
    different positions; -1 marks leaves that don't scale with slots)."""
    a = jax.eval_shape(lambda: mdl.init_cache(cfg, slots, max_len))
    b = jax.eval_shape(lambda: mdl.init_cache(cfg, slots + 1, max_len))

    def dim(sa, sb):
        for d, (x, y) in enumerate(zip(sa.shape, sb.shape)):
            if x != y:
                return d
        return -1

    return jax.tree.map(dim, a, b)


def _gather_slot(cache, bdims, slot):
    """Batch-1 view of one slot's rows (slot may be a traced scalar)."""
    return jax.tree.map(
        lambda x, d: x if d < 0
        else jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=d),
        cache, bdims)


def _scatter_slot(cache, small, bdims, slot):
    """Write a batch-1 cache back into the slot's rows."""
    return jax.tree.map(
        lambda big, s, d: big if d < 0
        else jax.lax.dynamic_update_slice_in_dim(
            big, s.astype(big.dtype), slot, axis=d),
        cache, small, bdims)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    def __init__(self, cfg, params, *, max_slots: int = 4,
                 max_len: int = 4096, eos_id: int = 2, seed: int = 0,
                 policy: Optional[AdmissionPolicy] = None,
                 prefill_chunk: Optional[int] = None,
                 kernel_backend: Optional[str] = None):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "the serving engine targets decoder-only families; "
                "whisper decode needs per-request encoder frames")
        if kernel_backend is not None:
            # deployment knob: pick the kernel impl (xla / pallas / ...)
            # for this engine; get_backend below re-validates the name
            cfg = dataclasses.replace(
                cfg, la=dataclasses.replace(cfg.la,
                                            backend=kernel_backend))
        self.cfg = cfg
        self.backend = get_backend(cfg)  # validates cfg at admission time
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.seed = seed
        self.prefill_chunk = prefill_chunk
        self.policy = policy if policy is not None else FixedSlots(max_slots)
        self.num_slots = self.policy.resolve_slots(cfg, max_len)
        self.max_slots = self.num_slots  # engine-v1 attribute, kept
        self.scheduler = Scheduler(self.num_slots)

        n = self.num_slots
        self.cache = mdl.init_cache(cfg, n, max_len)
        self._bdims = _cache_batch_dims(cfg, n, max_len)
        self.next_tokens = np.zeros((n,), np.int32)
        self.remaining = np.zeros((n,), np.int64)
        # per-slot sampling state, mirrored into the jitted decode step
        self._temp = np.zeros((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._topp = np.ones((n,), np.float32)
        self._keys = np.zeros((n, 2), np.uint32)
        self._params_of: List[Optional[smp.SamplingParams]] = [None] * n
        self._requests: Dict[int, Request] = {}

        def decode_fn(params, cache, tokens, keys, temp, topk, topp):
            logits, cache = mdl.decode_step(params, cfg, cache, tokens)
            toks, keys = smp.sample(logits, keys, temp, topk, topp)
            return toks, cache, keys

        self._decode = jax.jit(decode_fn)
        self._sample1 = jax.jit(smp.sample)   # prefill's first token
        self._prefill_fns: dict = {}          # (window_len, fresh) -> jit

    # -- public API ----------------------------------------------------
    def request(self, rid: int) -> Request:
        """The submitted Request (its generated tokens, state and
        finish_reason update in place as the engine advances)."""
        return self._requests[rid]

    def submit(self, req: Request):
        # cache positions written: len(prompt) prefill + max_new-1 decode
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)} tokens) + "
                f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
                f"positions but the engine was built with max_len="
                f"{self.max_len}")
        if req.generated is None:
            req.generated = []
        self._requests[req.rid] = req
        self.scheduler.submit(req)

    def step(self) -> List[StepOutput]:
        """Advance one engine iteration: admit + prefill queued requests
        into free slots, then decode one token for every decoding slot.
        Returns the StepOutputs emitted by this iteration."""
        outputs: List[StepOutput] = []
        for slot, req in self.scheduler.admit():
            outputs.append(self._admit_into(slot, req))
        outputs.extend(self._decode_once())
        return outputs

    def stream(self) -> Iterator[StepOutput]:
        """Yield StepOutputs until queue and slots drain."""
        while self.scheduler.has_work():
            yield from self.step()

    def run(self) -> Dict[int, list]:
        """Run until queue + slots drain.  Returns rid -> generated ids."""
        done: Dict[int, list] = {}
        for out in self.stream():
            if out.finished:
                done[out.rid] = self._requests[out.rid].generated
        return done

    # -- admission + chunked prefill -----------------------------------
    def _prefill_fn(self, n: int, fresh: bool):
        """Jitted: one n-token prompt window through the slot's own rows
        of the batched cache (gather -> prefill -> scatter).  `fresh`
        zeroes the slot's rows first (new admission over a stale slot);
        later windows continue from the carried position/state."""
        key = (n, fresh)
        if key not in self._prefill_fns:
            cfg, bdims = self.cfg, self._bdims

            def fn(params, cache, tokens, slot):
                small = _gather_slot(cache, bdims, slot)
                if fresh:
                    small = jax.tree.map(jnp.zeros_like, small)
                batch = {"tokens": tokens}
                if cfg.rope_kind == "mrope":
                    start = small["rope_pos"]          # (1,)
                    pos = (start[:, None]
                           + jnp.arange(n, dtype=jnp.int32)[None])
                    batch["positions"] = jnp.broadcast_to(
                        pos[None], (3, 1, n))
                logits, small = mdl.prefill(params, cfg, batch, small)
                return logits, _scatter_slot(cache, small, bdims, slot)

            self._prefill_fns[key] = jax.jit(fn)
        return self._prefill_fns[key]

    def _windows(self, prompt: list) -> List[list]:
        w = self.prefill_chunk
        if w is None or len(prompt) <= w:
            return [prompt]
        return [prompt[i:i + w] for i in range(0, len(prompt), w)]

    def _admit_into(self, slot: int, req: Request) -> StepOutput:
        req.state = RequestState.PREFILLING
        if req.generated is None:
            req.generated = []
        sp = req.resolved_sampling()
        self._params_of[slot] = sp
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        key = smp.request_key(sp, self.seed, req.rid)

        logits = None
        for i, window in enumerate(self._windows(req.prompt)):
            fn = self._prefill_fn(len(window), fresh=(i == 0))
            logits, self.cache = fn(
                self.params, self.cache,
                jnp.asarray(window, jnp.int32)[None],
                jnp.int32(slot))
        # the prefill already produced the first new token, sampled with
        # the request's own params + key (engine v1 greedy'd from here on)
        toks, key = self._sample1(
            logits, key[None],
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32))
        tok = int(toks[0])
        self._keys[slot] = np.array(key[0])
        self.next_tokens[slot] = tok
        self.remaining[slot] = req.max_new_tokens - 1
        req.generated.append(tok)
        req.state = RequestState.DECODING
        reason = self._finish_reason(slot, tok, sp)
        if reason:
            return self._finish(slot, req, tok, reason)
        return StepOutput(req.rid, tok, req.state)

    # -- decode --------------------------------------------------------
    def _decode_once(self) -> List[StepOutput]:
        active = list(self.scheduler.active())
        if not active:
            return []
        toks, self.cache, keys = self._decode(
            self.params, self.cache,
            jnp.asarray(self.next_tokens),
            jnp.asarray(self._keys),
            jnp.asarray(self._temp),
            jnp.asarray(self._topk),
            jnp.asarray(self._topp))
        nxt = np.asarray(toks)
        self._keys = np.array(keys)  # writable copy
        outputs = []
        for slot, req in active:
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.next_tokens[slot] = tok
            self.remaining[slot] -= 1
            reason = self._finish_reason(slot, tok, self._params_of[slot])
            if reason:
                outputs.append(self._finish(slot, req, tok, reason))
            else:
                outputs.append(StepOutput(req.rid, tok, req.state))
        return outputs

    # -- lifecycle -----------------------------------------------------
    def _finish_reason(self, slot: int, tok: int,
                       sp: smp.SamplingParams) -> Optional[str]:
        if tok == self.eos_id or tok in sp.stop:
            return "stop"
        if self.remaining[slot] <= 0:
            return "length"
        return None

    def _finish(self, slot: int, req: Request, tok: int,
                reason: str) -> StepOutput:
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        self.scheduler.release(slot)
        self._params_of[slot] = None
        self._temp[slot] = 0.0  # freed slots decode greedily (masked out)
        return StepOutput(req.rid, tok, req.state, finished=True,
                          finish_reason=reason)
