"""Continuous-batching serving engine — the request-lifecycle API.

Layering (serving API v3, scheduler v2):

  sampling.SamplingParams   per-request temperature / top-k / top-p /
                            stop tokens / seed, applied INSIDE the one
                            jitted decode step (greedy slots keep the
                            exact argmax path).
  scheduler.Scheduler       priority queue + slot array + victim choice;
                            admission policies (FixedSlots, ByteBudget)
                            resolve the slot count — ByteBudget from the
                            exact per-slot decode-cache bytes, so the
                            paper's O(D^2) linear state admits orders of
                            magnitude more concurrent sequences than the
                            softmax KV cache at the same HBM budget.
  Engine                    owns the batched cache + jitted steps and
                            surfaces the lifecycle: step() advances one
                            engine iteration and returns StepOutputs;
                            stream() yields them; run() drains to a
                            rid -> tokens dict.

TOKEN-INTERLEAVED STEPS (docs/serving.md "Scheduler v2"): every
`step()` spends a TokenBudget — first one decode token per decoding
slot (the latency-critical work), then as many chunked-prefill window
tokens as still fit (at least one window whenever prefill work exists,
so neither side can starve).  A long prompt therefore no longer runs
all its windows inside one step while co-resident requests' decode
stalls (the head-of-line baseline PR 9 pinned in tests/test_obs.py).

Mid-prefill slots are isolated through a host-held CARRY: each
partially-prefilled request's batch-1 cache rows live on its prefill
job, windows run batch-1 on merge(carry, live arena), and only on the
FINAL window is the carry scattered into the slot's rows of the
batched cache.  The batched decode step — which always runs the full
batch — meanwhile writes junk into that slot's (sink-routed, for
paged) rows, which the completion scatter fully overwrites.

PREEMPTION: a blocked higher-priority request picks a lower-priority
DECODING victim.  Eviction policy is per backend family —

  contiguous        snapshot the victim's batch-1 cache rows to device
                    buffers (O(max_len) KV for softmax, O(D^2) state
                    for linear/gla); resume scatters them back.
  paged KV          free the victim's pages (PagePool.free) and
                    drop-and-recompute its prefix on resume: re-prefill
                    prompt + generated[:-1], discard the final logits,
                    and restore the pending token + PRNG key — greedy
                    and seeded streams are provably identical to an
                    uninterrupted run (windowed prefill is exact).
  paged GLA state   the victim KEEPS its one O(D^2) state page (the
                    pool allocation survives preemption); the snapshot
                    is just the page-table row + position, so resume is
                    a single page swap — the paper's memory story as a
                    serving win.  When the blocker is PAGES rather than
                    slots, the page is freed instead and the victim
                    resumes by recompute (keeping it would deadlock the
                    higher-priority request).

PAGED-KV mode (docs/paged_kv.md): a PagedAdmission policy — or explicit
page_size/num_pages kwargs — switches the softmax KV cache to a shared
arena of fixed-size pages (mixers.cache.PagedKVCache).  The engine owns
a host-side PagePool: admission is gated on the pages a request
actually needs, prefill windows write straight into its allocated
pages, decode runs the "paged" kernel family (Pallas page-table
gather), and finishing a request returns its pages to the free list.
The last arena page is reserved as a write sink so retired and
mid-prefill slots — which keep decoding as batch padding — can never
corrupt a live page.

OBSERVABILITY (docs/observability.md): `Engine(tracer=...)` installs a
repro.obs Tracer and the engine emits the request lifecycle as events —
submit/reject, queued, admitted (via the Scheduler), per-window prefill
spans, per-token decode ticks, preempt/resume transitions, finish —
plus a per-step span with occupancy/queue gauges; the PagePool mirrors
its level into pages gauges.  Hooks are host-side only and gated on
`tracer is not None`, so the default engine runs zero instrumentation
and traced output is token-identical to untraced (pinned by
tests/test_obs.py).  The only behavioral difference under tracing is a
block_until_ready per prefill window so window spans measure device
time, not dispatch time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PagingCfg
from repro.mixers import get_backend, resolve_backend_name
from repro.mixers.cache import PagedGLAState, PagedKVCache
from repro.models import model as mdl
from repro.serve import sampling as smp
from repro.serve.paging import PagedAdmission, PagePool
from repro.serve.scheduler import AdmissionPolicy, ByteBudget, \
    FixedSlots, RequestState, Scheduler, StepOutput, TokenBudget
from repro.tune import timer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list                     # token ids
    max_new_tokens: int = 32
    temperature: float = 0.0         # shorthand; `sampling` wins if set
    priority: int = 0                # higher admits first & may preempt
    sampling: Optional[smp.SamplingParams] = None
    generated: Optional[list] = None
    state: RequestState = RequestState.QUEUED
    finish_reason: Optional[str] = None
    finish_t: Optional[float] = None   # Scheduler.release stamp (timer.now)

    def resolved_sampling(self) -> smp.SamplingParams:
        return self.sampling or smp.SamplingParams(
            temperature=self.temperature)


# ---------------------------------------------------------------------------
# Batched-cache slot addressing
# ---------------------------------------------------------------------------

def _cache_batch_dims(cfg, slots: int, max_len: int):
    """Per-leaf batch-dim pytree, found by growing the slot count by one
    under eval_shape (layer-stacked leaves carry their batch dim at
    different positions; -1 marks leaves that don't scale with slots —
    the shared paged arenas)."""
    a = jax.eval_shape(lambda: mdl.init_cache(cfg, slots, max_len))
    b = jax.eval_shape(lambda: mdl.init_cache(cfg, slots + 1, max_len))

    def dim(sa, sb):
        for d, (x, y) in enumerate(zip(sa.shape, sb.shape)):
            if x != y:
                return d
        return -1

    return jax.tree.map(dim, a, b)


def _gather_slot(cache, bdims, slot):
    """Batch-1 view of one slot's rows (slot may be a traced scalar)."""
    return jax.tree.map(
        lambda x, d: x if d < 0
        else jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=d),
        cache, bdims)


def _scatter_slot(cache, small, bdims, slot):
    """Write a batch-1 cache back into the slot's rows.  Leaves with no
    batch dim (the paged-KV arenas, shared across slots) pass through
    from `small`: prefill writes the slot's pages in place, so the
    updated arena IS the new cache leaf."""
    return jax.tree.map(
        lambda big, s, d: s.astype(big.dtype) if d < 0
        else jax.lax.dynamic_update_slice_in_dim(
            big, s.astype(big.dtype), slot, axis=d),
        cache, small, bdims)


@dataclasses.dataclass
class _PrefillJob:
    """Host-side progress of one partially-prefilled slot.

    `carry` is the request's OWN batch-1 cache rows (position, KV rows
    or recurrent state, page-table row); the batched cache's slot rows
    stay junk/sink-routed until the final window scatters the finished
    carry in — so the batched decode step can run over the slot
    mid-prefill without corrupting anything."""

    req: Request
    windows: List[list]              # prompt windows still to run
    windows_dev: List                # same windows, device int32 [1, n]
    carry: object                    # batch-1 cache pytree
    resume: Optional[dict] = None    # suspended host state (recompute)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    def __init__(self, cfg, params, *, max_slots: int = 4,
                 max_len: int = 4096, eos_id: int = 2, seed: int = 0,
                 policy: Optional[AdmissionPolicy] = None,
                 prefill_chunk: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 kernel_backend: Optional[str] = None,
                 fused_decode: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 tracer=None):
        # repro.obs Tracer (or None = zero instrumentation); set first
        # so the Scheduler and PagePool constructed below share it
        self.tracer = tracer
        if cfg.family == "encdec":
            raise NotImplementedError(
                "the serving engine targets decoder-only families; "
                "whisper decode needs per-request encoder frames")
        if kernel_backend is not None:
            # deployment knob: pick the kernel impl (xla / pallas / ...)
            # for this engine; get_backend below re-validates the name
            cfg = dataclasses.replace(
                cfg, la=dataclasses.replace(cfg.la,
                                            backend=kernel_backend))
        if fused_decode is not None:
            # deployment knob: route decode through the fused
            # single-kernel step families (docs/fused_decode.md) or pin
            # the legacy unfused composition — parity is tested via
            # tests/helpers.assert_engine_identity
            cfg = dataclasses.replace(
                cfg, la=dataclasses.replace(cfg.la,
                                            fused_decode=fused_decode))
        self.policy = policy if policy is not None else FixedSlots(max_slots)
        # paged-KV mode: PagedAdmission implies it (arena sized from the
        # byte budget); --page-size/--num-pages request it explicitly.
        # The LAST arena page is reserved as a write sink: retired slots
        # keep decoding as batch padding, and their table rows point at
        # it so those writes can never corrupt a live request's pages.
        if isinstance(self.policy, PagedAdmission):
            if page_size is not None or num_pages is not None:
                raise ValueError(
                    "PagedAdmission already fixes page_size/num_pages "
                    "from its byte budget; drop the engine kwargs")
            page_size = self.policy.page_size
            num_pages = self.policy.resolve_num_pages(cfg)
        elif page_size is not None and isinstance(self.policy, ByteBudget):
            # ByteBudget's per-slot charge collapses to the int32
            # page-table row once cfg.paging is set (the arena has no
            # batch dim), so it would resolve a nonsense slot count —
            # the page-aware byte policy IS PagedAdmission
            raise ValueError(
                "ByteBudget admission cannot size a paged engine; use "
                "PagedAdmission(budget_bytes, page_size=...) instead")
        if num_pages is not None and page_size is None:
            raise ValueError(
                "num_pages without page_size: set page_size to enable "
                "the paged-KV cache")
        if page_size is not None:
            # gla pages hold one slot's recurrent STATE each; softmax
            # pages hold page_size KV rows (docs/paged_kv.md)
            state_paged = resolve_backend_name(cfg) == "gla"
            pages_per_seq = 1 if state_paged \
                else -(-max_len // page_size)
            if num_pages is None:
                # default arena: worst case for every slot, plus sink —
                # same HBM as contiguous, still page-granular admission
                n_slots = self.policy.resolve_slots(cfg, max_len)
                num_pages = n_slots * pages_per_seq + 1
            cfg = dataclasses.replace(
                cfg, paging=PagingCfg(page_size=page_size,
                                      num_pages=num_pages))
        self.cfg = cfg
        self.backend = get_backend(cfg)  # validates cfg at admission time
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.seed = seed
        self.prefill_chunk = prefill_chunk
        self.num_slots = self.policy.resolve_slots(cfg, max_len)
        self.max_slots = self.num_slots  # engine-v1 attribute, kept
        self.scheduler = Scheduler(self.num_slots, tracer=tracer)
        # per-step token budget (scheduler v2): decode tokens for every
        # decoding slot + at least one prefill window fit by default
        if token_budget is not None and token_budget < 1:
            raise ValueError(
                f"token_budget must be >= 1, got {token_budget}")
        self.token_budget = token_budget if token_budget is not None \
            else self.num_slots + (prefill_chunk if prefill_chunk
                                   else max_len)
        self.last_step_budget: Dict[str, int] = {
            "total": self.token_budget, "decode": 0, "prefill": 0}
        self.preemption_count = 0

        n = self.num_slots
        self.cache = mdl.init_cache(cfg, n, max_len)
        self._bdims = _cache_batch_dims(cfg, n, max_len)
        self._flat_dims = jax.tree.leaves(self._bdims)
        # contiguous caches have no shared-arena leaves, so the
        # per-window merge/publish tree traversals are identity maps —
        # skip them (the window step is on the inter-token tail path)
        self._has_arena = any(d < 0 for d in self._flat_dims)
        self._carry0 = None   # shared zero carry template, built lazily
        self.pool: Optional[PagePool] = None
        self._state_paged = False
        if cfg.paging is not None:
            # dense-prefix (MoE first_dense_layers) caches carry extra
            # per-layer paged caches under "prefix" whose page tables
            # the engine does not manage — reject rather than serve
            # silently-wrong prefix attention
            blocks = self.cache.get("blocks")
            if not isinstance(blocks, (PagedKVCache, PagedGLAState)) \
                    or "prefix" in self.cache:
                raise NotImplementedError(
                    "paged serving needs the plain decoder cache "
                    "layout (softmax or gla attention backend, no "
                    "dense-prefix layers)")
            self._state_paged = isinstance(blocks, PagedGLAState)
            self._zero_pages = None   # donated page-wipe jit, built lazily
            self._sink_page = cfg.paging.num_pages - 1
            self._pages_per_seq = blocks.page_table.shape[-1]
            # model.init_cache stacks layers with zeros_like, which
            # wipes the mixer's sink-page fill — re-point EVERY row at
            # the sink so slots that were never admitted pad their
            # decode writes there, not into arena page 0
            self.cache["blocks"] = blocks._replace(
                page_table=jnp.full_like(blocks.page_table,
                                         self._sink_page))
            self.pool = PagePool(cfg.paging.num_pages - 1,
                                 cfg.paging.page_size, tracer=tracer)
        self.next_tokens = np.zeros((n,), np.int32)
        self.remaining = np.zeros((n,), np.int64)
        # per-slot sampling state, mirrored into the jitted decode step
        self._temp = np.zeros((n,), np.float32)
        self._topk = np.zeros((n,), np.int32)
        self._topp = np.ones((n,), np.float32)
        self._keys = np.zeros((n, 2), np.uint32)
        self._params_of: List[Optional[smp.SamplingParams]] = [None] * n
        self._requests: Dict[int, Request] = {}
        self._jobs: Dict[int, _PrefillJob] = {}       # slot -> prefill job
        self._suspended: Dict[int, dict] = {}         # rid -> evicted state
        self._prepped: Dict[int, dict] = {}           # rid -> device consts
        self._zero_key = jnp.zeros((1, 2), jnp.uint32)
        self._slot_ix = [jnp.int32(i) for i in range(n)]
        self._samp_cache: Dict[tuple, tuple] = {}     # triple -> dev arrays
        self._root_key = jax.random.PRNGKey(seed)     # fold_in(root, rid)
        self._true = jnp.asarray(True)
        self._false = jnp.asarray(False)
        self._rid0 = jnp.uint32(0)

        def decode_fn(params, cache, tokens, keys, temp, topk, topp):
            logits, cache = mdl.decode_step(params, cfg, cache, tokens)
            toks, keys = smp.sample(logits, keys, temp, topk, topp)
            return toks, cache, keys

        # the cache is DONATED: XLA updates the KV / state arenas in
        # place instead of copying them every token (_decode_once
        # immediately rebinds self.cache from the result, so the stale
        # buffer is never touched).  analysis/hlo.py's
        # assert_cache_donation pins that the aliasing survives
        # compilation (tests/test_decode_fused.py).
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))
        self._prefill_fns: dict = {}          # window_len -> jit
        self._complete_fns: dict = {}         # final-window fused jit

        bdims = self._bdims
        flat_dims = self._flat_dims

        def snap_fn(cache, slot):
            # batch-dim leaves only: the shared paged arenas stay out of
            # the snapshot (their buffers are donated every decode step;
            # a held reference would go stale)
            flat = jax.tree.leaves(cache)
            return [jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=d)
                    for x, d in zip(flat, flat_dims) if d >= 0]

        def restore_fn(cache, snap, slot):
            it = iter(snap)

            def put(x, d):
                if d < 0:
                    return x
                return jax.lax.dynamic_update_slice_in_dim(
                    x, next(it).astype(x.dtype), slot, axis=d)

            return jax.tree.map(put, cache, bdims)

        # one jit serves both prefill COMPLETION (scatter the finished
        # carry's batch leaves into the slot) and preemption RESUME
        # (scatter the victim's snapshot back); the cache is donated so
        # the write is in place
        self._snap = jax.jit(snap_fn)
        self._restore = jax.jit(restore_fn, donate_argnums=(0,))

    # -- public API ----------------------------------------------------
    def request(self, rid: int) -> Request:
        """The submitted Request (its generated tokens, state and
        finish_reason update in place as the engine advances)."""
        return self._requests[rid]

    def submit(self, req: Request):
        if self.tracer is not None:
            self.tracer.request_submitted(req.rid, len(req.prompt),
                                          req.max_new_tokens)
        if req.max_new_tokens < 1:
            # prefill always emits the token it sampled, so max_new=0
            # would still generate one token (and under-count its cache
            # footprint) — reject instead of silently over-generating
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "max_new_tokens")
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 (the "
                f"prompt's final logits always yield one sampled "
                f"token), got {req.max_new_tokens}")
        if len(req.prompt) == 0:
            # an empty prompt would drive a 0-token window into the
            # jitted prefill path — fail here, not inside jit
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "empty")
            raise ValueError(
                f"request {req.rid}: empty prompt (prefill needs at "
                f"least one token to produce logits)")
        live = self._requests.get(req.rid)
        if live is not None and live.state is not RequestState.FINISHED:
            # no tracer reject here: stamping rid's record would
            # corrupt the LIVE request's span tree
            raise ValueError(
                f"request id {req.rid} is already live "
                f"(state={live.state.value}); a reused rid would "
                f"clobber its record and page table")
        # cache positions written: len(prompt) prefill + max_new-1 decode
        need = len(req.prompt) + req.max_new_tokens - 1
        if need > self.max_len:
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "max_len")
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)} tokens) + "
                f"max_new_tokens ({req.max_new_tokens}) needs {need} cache "
                f"positions but the engine was built with max_len="
                f"{self.max_len}")
        if self.pool is not None \
                and self._req_pages(req) > self.pool.num_pages:
            # would never admit: the queue would deadlock behind it
            kind = "state" if self._state_paged else "KV"
            detail = "a page holds one slot's whole recurrent state" \
                if self._state_paged \
                else f"page_size={self.pool.page_size}"
            if self.tracer is not None:
                self.tracer.request_rejected(req.rid, "arena")
            raise ValueError(
                f"request {req.rid} needs {self._req_pages(req)} "
                f"{kind} pages but the whole arena has "
                f"{self.pool.num_pages} allocatable pages ({detail})")
        if req.generated is None:
            req.generated = []
        self._prep(req)
        self._requests[req.rid] = req
        self.scheduler.submit(req)

    def _prep(self, req: Request) -> None:
        """Pre-stage the request's device constants at submit time, and
        keep even the submit itself nearly transfer-free — submit often
        lands between co-resident streams' token emissions, so a burst
        of tiny host->device dispatches here (or, worse, on the
        admission / completion steps) would show up as an inter-token
        spike.  Three tricks:

          * the sampling triple (temp, top_k, top_p) is interned in an
            engine-wide cache — most requests share a few triples;
          * the PRNG key is NOT derived here: the default key is
            fold_in(root, rid), which the fused completion program
            computes on device from the rid scalar (a request's own
            `seed` takes the rare host path);
          * prompt windows ship through ONE `jax.device_put` call
            (eager per-window `jnp.asarray` costs ~4x more here, and
            device-side row slicing would compile a program per row)."""
        sp = req.resolved_sampling()
        trip = (sp.temperature, sp.top_k, sp.top_p)
        samp = self._samp_cache.get(trip)
        if samp is None:
            samp = (jnp.asarray([sp.temperature], jnp.float32),
                    jnp.asarray([sp.top_k], jnp.int32),
                    jnp.asarray([sp.top_p], jnp.float32))
            self._samp_cache[trip] = samp
        if sp.seed is not None:
            key, rid_dev, use_rid = (smp.request_key(sp, self.seed,
                                                     req.rid)[None],
                                     self._rid0, self._false)
        else:
            key, rid_dev, use_rid = (self._zero_key,
                                     jnp.uint32(req.rid), self._true)
        self._prepped[req.rid] = {
            "samp": samp, "key": key, "rid": rid_dev, "use_rid": use_rid,
            "windows": self._put_windows(self._windows(req.prompt))}

    @staticmethod
    def _put_windows(windows: List[list]) -> List:
        """All of a prompt's windows to device in one transfer call."""
        return jax.device_put([np.asarray(w, np.int32)[None]
                               for w in windows])

    def step(self) -> List[StepOutput]:
        """Advance one engine iteration under the token budget: admit
        (preempting for blocked higher-priority requests), decode one
        token per decoding slot, then run prefill windows with the
        remaining budget.  Returns the StepOutputs emitted."""
        tr = self.tracer
        t0 = timer.now() if tr is not None else 0.0
        budget = TokenBudget(self.token_budget)
        outputs: List[StepOutput] = []
        self._admit_and_preempt(outputs)
        outputs.extend(self._decode_once(budget))
        self._prefill_round(budget, outputs)
        self.last_step_budget = {"total": budget.total,
                                 "decode": budget.decode_tokens,
                                 "prefill": budget.prefill_tokens}
        if tr is not None:
            active = sum(1 for _ in self.scheduler.active())
            tr.engine_step(t0, active, self.num_slots,
                           len(self.scheduler.queue))
        return outputs

    def stream(self) -> Iterator[StepOutput]:
        """Yield StepOutputs until queue and slots drain."""
        while self.scheduler.has_work():
            yield from self.step()

    def run(self) -> Dict[int, list]:
        """Run until queue + slots drain.  Returns rid -> generated ids."""
        done: Dict[int, list] = {}
        for out in self.stream():
            if out.finished:
                done[out.rid] = self._requests[out.rid].generated
        return done

    # -- admission + preemption ----------------------------------------
    def _can_admit(self, req) -> bool:
        """Beyond a free slot, a paged engine needs the request's pages
        to be free RIGHT NOW (its worst-case token footprint — prompt
        plus every decode position it may write; ONE state page for the
        gla layout, whatever the token count).  The check RESERVES the
        pages: Scheduler.admit may probe several queued requests for
        one batch of free slots before the engine prefills any of them,
        so a pure lookahead would over-admit against the same free
        pages (a True verdict is always followed by admission, so a
        reservation never leaks).  A preempted gla request that KEPT
        its state page re-admits against that standing reservation."""
        if self.pool is None:
            return True
        if self.pool.holds(req.rid):
            return True
        need = self._req_pages(req)
        if need > self.pool.free_pages:
            return False
        self.pool.allocate_pages(req.rid, need)
        return True

    def _admit_and_preempt(self, outputs: List[StepOutput]) -> None:
        """Fill free slots in priority order; while the queue head is
        still blocked and outranks a decoding occupant, evict victims
        (freeing their pages when pages are the blocker) and retry."""
        while True:
            for slot, req in self.scheduler.admit(self._can_admit):
                self._place(slot, req)
            head = self.scheduler.peek()
            if head is None:
                break
            if not self._preempt_for(head, outputs):
                break

    def _preempt_for(self, head, outputs: List[StepOutput]) -> bool:
        """Try to unblock `head` by preempting strictly-lower-priority
        work; True if anything was freed (caller retries admission)."""
        page_blocked = (
            self.pool is not None and not self.pool.holds(head.rid)
            and self._req_pages(head) > self.pool.free_pages)
        victim_slot = self.scheduler.pick_victim(
            getattr(head, "priority", 0))
        if victim_slot is not None:
            outputs.append(
                self._preempt(victim_slot, need_pages=page_blocked))
            return True
        if page_blocked:
            # no decoding victim, but preempted lower-priority requests
            # may still hold state pages (the gla page-keep policy) —
            # reclaim them (demoting their resume to recompute) rather
            # than deadlock the higher-priority head
            freed = False
            for req in self.scheduler.queued():
                if req is head or req.state is not RequestState.PREEMPTED:
                    continue
                if getattr(req, "priority", 0) >= head.priority:
                    continue
                if not self.pool.holds(req.rid):
                    continue
                self.pool.free(req.rid)
                st = self._suspended.get(req.rid)
                if st is not None:
                    st["snap"] = None
                freed = True
                if self._req_pages(head) <= self.pool.free_pages:
                    break
            return freed
        return False

    def _preempt(self, slot: int, need_pages: bool) -> StepOutput:
        """Evict the DECODING occupant of `slot` (docs/serving.md lists
        the per-backend policies).  The suspended host state (pending
        token, PRNG key, remaining budget, optional device snapshot) is
        parked under the rid until resume."""
        req = self.scheduler.slots[slot]
        snap = None
        if self.pool is not None:
            if self._state_paged and not need_pages:
                # the paper's cheap-preemption story: the O(D^2) state
                # page stays allocated; the snapshot is just the
                # page-table row + position, resume is one page swap
                policy = "page_keep"
                snap = list(self._snap(self.cache, self._slot_ix[slot]))
            else:
                policy = "recompute"
                self.pool.free(req.rid)
            # padding decode writes from the vacated lane must land in
            # the sink, never in kept or re-issued pages
            self._set_page_row(slot, [])
            if self.tracer is not None:
                self.tracer.sink_repoint()
        else:
            policy = "snapshot"
            snap = list(self._snap(self.cache, self._slot_ix[slot]))
        self._suspended[req.rid] = {
            "snap": snap, "policy": policy,
            "keys": self._keys[slot].copy(),
            "remaining": int(self.remaining[slot]),
            "next": int(self.next_tokens[slot]),
        }
        self.scheduler.preempt(slot)
        self._params_of[slot] = None
        self._temp[slot] = 0.0   # vacated lane decodes greedily (masked)
        self.preemption_count += 1
        if self.tracer is not None:
            self.tracer.request_preempted(req.rid, slot, policy)
        return StepOutput(req.rid, None, RequestState.PREEMPTED)

    def _place(self, slot: int, req: Request) -> None:
        """Put an admitted request into its slot: restore a snapshot
        victim straight to DECODING, or start a prefill job (fresh
        prompt, or prompt + generated prefix for drop-and-recompute)."""
        st = self._suspended.pop(req.rid, None)
        tr = self.tracer
        if st is not None and st["snap"] is not None:
            # single-swap resume: scatter the snapshot rows back (for
            # paged gla that is just the page-table row + position —
            # the state page itself never moved)
            self.cache = self._restore(self.cache, st["snap"],
                                       self._slot_ix[slot])
            self._keys[slot] = st["keys"]
            self.remaining[slot] = st["remaining"]
            self.next_tokens[slot] = st["next"]
            self._set_sampling(slot, req)
            req.state = RequestState.DECODING
            if tr is not None:
                tr.request_resumed(req.rid, slot, st["policy"])
            return
        if req.generated is None:
            req.generated = []
        if st is not None:
            # drop-and-recompute: re-prefill everything already in the
            # cache before eviction (prompt + all generated tokens but
            # the pending one); the rebuilt KV/state is exactly the
            # uninterrupted cache, so restoring the pending token + key
            # resumes the identical stream
            prompt = list(req.prompt) + req.generated[:-1]
            windows = self._windows(prompt)
            windows_dev = self._put_windows(windows)
        else:
            prompt = req.prompt
            windows = self._windows(prompt)
            # pre-staged at submit; copy — the job pops as it runs
            windows_dev = list(self._prepped[req.rid]["windows"])
        # shallow copy: the paged branch below replaces carry["blocks"],
        # which must not leak into the shared template (or into another
        # job admitted in the same step)
        carry = dict(self._fresh_carry())
        if self.pool is not None:
            pages = self.pool.table(req.rid)
            self._zero_state_pages(pages)
            row = np.full((self._pages_per_seq,), self._sink_page,
                          np.int32)
            row[:len(pages)] = pages
            blocks = carry["blocks"]
            carry["blocks"] = blocks._replace(
                page_table=jnp.broadcast_to(
                    jnp.asarray(row), blocks.page_table.shape))
        self._jobs[slot] = _PrefillJob(req=req, windows=windows,
                                       windows_dev=windows_dev,
                                       carry=carry, resume=st)
        req.state = RequestState.PREFILLING
        if st is not None and tr is not None:
            tr.request_resumed(req.rid, slot, "recompute")

    # -- chunked prefill (carry-based, budget-driven) -------------------
    def _fresh_carry(self):
        """A zeroed batch-1 cache for a new prefill job.  The zeros are
        slot-independent and immutable (every window call produces a
        NEW carry), so one template serves every admission — building
        fresh device zeros per admission would put a burst of tiny
        dispatches on the admission step's inter-token delta.  The
        template's arena leaves may go stale (decode donates those
        buffers); they are never read — `_merge_carry` swaps in the
        live arenas before every window."""
        if self._carry0 is None:
            def fresh(x, d):
                if d < 0:
                    return x
                shape = list(x.shape)
                shape[d] = 1
                return jnp.zeros(shape, x.dtype)

            self._carry0 = jax.tree.map(fresh, self.cache, self._bdims)
        return self._carry0

    def _merge_carry(self, carry):
        """The window's batch-1 input: the job's own batch rows + the
        LIVE shared arenas (decode donates + rebinds them every step,
        so the carry's arena refs go stale between windows)."""
        if not self._has_arena:
            return carry
        return jax.tree.map(
            lambda c, big, d: big if d < 0 else c,
            carry, self.cache, self._bdims)

    def _zero_state_pages(self, pages: List[int]) -> None:
        """gla paged state accumulates — a newly assigned page must not
        seed the recurrence with a previous request's state.  (KV pages
        need no wipe: attention masks by length and rows are
        overwritten before they are exposed.)"""
        if not (self._state_paged and pages):
            return
        blocks = self.cache["blocks"]
        # donated jit so XLA scatters the zeros in place — a bare
        # .at[].set here would materialize a full copy of every
        # layer's state arena per admission
        if self._zero_pages is None:
            self._zero_pages = jax.jit(
                lambda s, p, idx: (s.at[:, idx].set(0.0),
                                   p.at[:, idx].set(0.0)),
                donate_argnums=(0, 1))
        s_z, p_z = self._zero_pages(blocks.s_pages, blocks.p_pages,
                                    jnp.asarray(pages, jnp.int32))
        self.cache["blocks"] = blocks._replace(s_pages=s_z, p_pages=p_z)

    def _set_page_row(self, slot: int, pages: List[int]) -> None:
        """Point the BATCHED cache's page-table row for `slot` (all
        layers) at `pages`, padding the unallocated tail with the
        reserved sink page.  With the carry design this is only ever
        called with [] — mid-prefill and vacated lanes sink-route their
        padding decode writes; the completion scatter installs the real
        row from the carry."""
        row = np.full((self._pages_per_seq,), self._sink_page, np.int32)
        row[:len(pages)] = pages
        blocks = self.cache["blocks"]
        self.cache["blocks"] = blocks._replace(
            page_table=blocks.page_table.at[:, slot, :].set(
                jnp.asarray(row)))

    def _prefill_fn(self, n: int):
        """Jitted: one n-token prompt window on a batch-1 cache,
        continuing from the carried position/state.  No gather/scatter
        and no fresh/continue split — the carry is born zeroed, so one
        compiled program per window LENGTH serves every window."""
        if n not in self._prefill_fns:
            cfg = self.cfg

            def fn(params, small, tokens):
                batch = {"tokens": tokens}
                if cfg.rope_kind == "mrope":
                    start = small["rope_pos"]          # (1,)
                    pos = (start[:, None]
                           + jnp.arange(n, dtype=jnp.int32)[None])
                    batch["positions"] = jnp.broadcast_to(
                        pos[None], (3, 1, n))
                return mdl.prefill(params, cfg, batch, small)

            self._prefill_fns[n] = jax.jit(fn)
        return self._prefill_fns[n]

    def _complete_fn(self, n: int):
        """Jitted FINAL window: prefill the last n prompt tokens, then —
        in the same program — scatter the finished carry into the
        slot's rows of the batched cache and sample the first token
        from the window's logits.  One dispatch instead of three
        (window + restore + sample), so the step that completes a
        prefill costs no more than any other window step — the
        inter-token p99 bound in tests/test_obs.py leans on this.

        The batched cache rides in as its batch-dim LEAVES only,
        donated so the scatter is in place.  Donating the full cache
        would be unsafe on a paged engine: its arena leaves alias the
        merged carry input.  The arenas come out of the window's carry
        instead (the window updated them in place), so the returned
        tree is the complete new cache either way."""
        if n not in self._complete_fns:
            cfg = self.cfg
            bdims = self._bdims
            root = self._root_key   # jit constant

            def fn(params, small, tokens, cache_batch, slot, key,
                   rid, use_rid, temp, topk, topp):
                batch = {"tokens": tokens}
                if cfg.rope_kind == "mrope":
                    start = small["rope_pos"]          # (1,)
                    pos = (start[:, None]
                           + jnp.arange(n, dtype=jnp.int32)[None])
                    batch["positions"] = jnp.broadcast_to(
                        pos[None], (3, 1, n))
                logits, carry = mdl.prefill(params, cfg, batch, small)
                it = iter(cache_batch)

                def put(c, d):
                    if d < 0:
                        return c   # arena: the window's in-place update
                    big = next(it)
                    return jax.lax.dynamic_update_slice_in_dim(
                        big, c.astype(big.dtype), slot, axis=d)

                cache = jax.tree.map(put, carry, bdims)
                # default key = fold_in(root, rid), derived ON DEVICE —
                # bit-identical to smp.request_key on host, but keeps
                # the threefry dispatches off the submit path; an
                # explicit SamplingParams.seed rides in as `key`
                derived = jax.random.fold_in(root, rid)[None]
                key = jnp.where(use_rid, derived, key)
                toks, key = smp.sample(logits, key, temp, topk, topp)
                return toks, key, cache

            self._complete_fns[n] = jax.jit(fn, donate_argnums=(3,))
        return self._complete_fns[n]

    def _windows(self, prompt: list) -> List[list]:
        w = self.prefill_chunk
        if w is None or len(prompt) <= w:
            return [prompt]
        return [prompt[i:i + w] for i in range(0, len(prompt), w)]

    def _run_window(self, slot: int, job: _PrefillJob) -> None:
        window = job.windows.pop(0)
        tokens = job.windows_dev.pop(0)
        fn = self._prefill_fn(len(window))
        tr = self.tracer
        t0 = timer.now() if tr is not None else 0.0
        logits, carry = fn(self.params, self._merge_carry(job.carry),
                           tokens)
        job.carry = carry
        # arena leaves (paged KV / state) were updated in place by the
        # window — publish them so decode and other jobs see the writes
        if self._has_arena:
            self.cache = jax.tree.map(
                lambda big, c, d: c if d < 0 else big,
                self.cache, carry, self._bdims)
        if tr is not None:
            # span measures device time; the sync changes no values
            jax.block_until_ready(logits)
            tr.prefill_window(job.req.rid, slot, len(window), t0)

    def _set_sampling(self, slot: int, req: Request) -> None:
        sp = req.resolved_sampling()
        self._params_of[slot] = sp
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p

    def _run_final_window(self, slot: int,
                          job: _PrefillJob) -> Optional[StepOutput]:
        """Run the LAST window through the fused completion program:
        the carry lands in the slot's rows of the batched cache
        (overwriting the junk the padded decode wrote there) and the
        first token is sampled, all in one dispatch.  On a recompute
        resume the sample is discarded and the pending token + PRNG key
        are restored instead — that token was already emitted before
        eviction."""
        window = job.windows.pop(0)
        tokens = job.windows_dev.pop(0)
        req = job.req
        sp = req.resolved_sampling()
        prep = self._prepped[req.rid]
        temp, topk, topp = prep["samp"]
        fn = self._complete_fn(len(window))
        tr = self.tracer
        t0 = timer.now() if tr is not None else 0.0
        cache_batch = [x for x, d in zip(jax.tree.leaves(self.cache),
                                         self._flat_dims) if d >= 0]
        toks, key, self.cache = fn(
            self.params, self._merge_carry(job.carry), tokens,
            cache_batch, self._slot_ix[slot],
            prep["key"], prep["rid"], prep["use_rid"],
            temp, topk, topp)
        if tr is not None:
            # span measures device time; the sync changes no values
            jax.block_until_ready(toks)
            tr.prefill_window(req.rid, slot, len(window), t0)
        self._set_sampling(slot, req)
        del self._jobs[slot]
        if job.resume is not None:
            # the rebuilt cache equals the uninterrupted one
            self._keys[slot] = job.resume["keys"]
            self.remaining[slot] = job.resume["remaining"]
            self.next_tokens[slot] = job.resume["next"]
            req.state = RequestState.DECODING
            return None
        tok = int(toks[0])
        self._keys[slot] = np.array(key[0])
        self.next_tokens[slot] = tok
        self.remaining[slot] = req.max_new_tokens - 1
        req.generated.append(tok)
        if tr is not None:
            tr.token_emitted(req.rid, slot)
        req.state = RequestState.DECODING
        reason = self._finish_reason(slot, tok, sp)
        if reason:
            return self._finish(slot, req, tok, reason)
        return StepOutput(req.rid, tok, req.state)

    def _prefill_round(self, budget: TokenBudget,
                       outputs: List[StepOutput]) -> None:
        """Spend the step's remaining budget on prefill windows, round-
        robin over mid-prefill slots in (priority, admission) order.
        At least ONE window runs whenever prefill work exists — the
        budget shapes the decode/prefill mix, it cannot starve prefill
        into a livelock."""
        ran_any = False
        while True:
            cands = self.scheduler.prefilling()
            if not cands:
                return
            progressed = False
            for slot, req in cands:
                job = self._jobs[slot]
                if not budget.fits(len(job.windows[0])):
                    continue
                budget.spend_prefill(len(job.windows[0]))
                self._spend_window(slot, job, outputs)
                progressed = ran_any = True
            if not progressed:
                break
        if not ran_any:
            cands = self.scheduler.prefilling()
            if not cands:
                return
            slot, req = cands[0]
            job = self._jobs[slot]
            budget.spend_prefill(len(job.windows[0]))
            self._spend_window(slot, job, outputs)

    def _spend_window(self, slot: int, job: _PrefillJob,
                      outputs: List[StepOutput]) -> None:
        if len(job.windows) == 1:
            out = self._run_final_window(slot, job)
            if out is not None:
                outputs.append(out)
        else:
            self._run_window(slot, job)

    # -- decode --------------------------------------------------------
    def _decode_once(self, budget: TokenBudget) -> List[StepOutput]:
        decoding = list(self.scheduler.decoding())
        if not decoding:
            return []
        budget.spend_decode(len(decoding))
        toks, self.cache, keys = self._decode(
            self.params, self.cache,
            jnp.asarray(self.next_tokens),
            jnp.asarray(self._keys),
            jnp.asarray(self._temp),
            jnp.asarray(self._topk),
            jnp.asarray(self._topp))
        nxt = np.asarray(toks)
        self._keys = np.array(keys)  # writable copy
        tr = self.tracer
        outputs = []
        for slot, req in decoding:
            tok = int(nxt[slot])
            req.generated.append(tok)
            if tr is not None:
                tr.token_emitted(req.rid, slot)
            self.next_tokens[slot] = tok
            self.remaining[slot] -= 1
            reason = self._finish_reason(slot, tok, self._params_of[slot])
            if reason:
                outputs.append(self._finish(slot, req, tok, reason))
            else:
                outputs.append(StepOutput(req.rid, tok, req.state))
        return outputs

    # -- lifecycle -----------------------------------------------------
    def _finish_reason(self, slot: int, tok: int,
                       sp: smp.SamplingParams) -> Optional[str]:
        if tok == self.eos_id or tok in sp.stop:
            return "stop"
        if self.remaining[slot] <= 0:
            return "length"
        return None

    def _finish(self, slot: int, req: Request, tok: int,
                reason: str) -> StepOutput:
        req.state = RequestState.FINISHED
        t_fin = self.scheduler.release(slot, finish_reason=reason)
        req.finish_t = t_fin
        if self.pool is not None:
            # return the pages and re-point the slot at the sink page:
            # the retired slot keeps decoding as batch padding, and its
            # writes must not land in pages the free list may re-issue
            self.pool.free(req.rid)
            self._set_page_row(slot, [])
            if self.tracer is not None:
                self.tracer.sink_repoint()
        if self.tracer is not None:
            self.tracer.request_finished(req.rid, reason, t_fin)
        self._prepped.pop(req.rid, None)
        self._params_of[slot] = None
        self._temp[slot] = 0.0  # freed slots decode greedily (masked out)
        return StepOutput(req.rid, tok, req.state, finished=True,
                          finish_reason=reason, t=t_fin)

    def _req_pages(self, req) -> int:
        """Arena pages the request needs for its whole lifetime."""
        if self._state_paged:
            return 1   # one O(D^2) state page, independent of tokens
        return self.pool.pages_needed(self._token_footprint(req))

    @staticmethod
    def _token_footprint(req) -> int:
        # cache positions written: len(prompt) prefill + max_new-1
        # decode (max_new >= 1 is enforced at submit, so this never
        # under-counts)
        return len(req.prompt) + req.max_new_tokens - 1

    # -- paged-KV stats (benchmarks / launcher artifacts) --------------
    def page_stats(self) -> Optional[Dict[str, int]]:
        """None unless paged; else allocatable / free / in-use pages."""
        if self.pool is None:
            return None
        return {"page_size": self.pool.page_size,
                "num_pages": self.pool.num_pages,
                "free_pages": self.pool.free_pages,
                "pages_in_use": self.pool.pages_in_use}
