"""Seeded-defect fixtures: one deliberately broken kernel per analyzer.

These are the analyzer's own regression tests (tests/test_check.py):
each fixture violates exactly one contract and the corresponding audit
must report exactly that rule ID.  They are NOT registered in
`kernels/ops.py` — they exist to prove the analyzer would catch the
defect if a real kernel regressed into it.

  oob_blocked_sum        index_map walks one block past the extent
                         -> REPRO-B001 (check via bounds.record_launches)
  quadratic_residual_fwd custom-VJP fwd rule saving the (N, N)
                         attention matrix -> REPRO-J001
  unguarded_bf16_matmul  bf16 contraction without
                         preferred_element_type -> REPRO-J002
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.check import bounds, jaxpr_audit
from repro.check.findings import Finding


# ---------------------------------------------------------------------------
# REPRO-B001: off-by-one index map
# ---------------------------------------------------------------------------

def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def oob_blocked_sum(x, block: int = 16):
    """Blocked copy whose INPUT index map reads block i+1 — the last
    grid step indexes one block past the array."""
    n = x.shape[0]
    t = n // block
    return pl.pallas_call(
        _copy_kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i + 1,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
    )(x)


def audit_oob_fixture() -> list[Finding]:
    with bounds.record_launches() as launches:
        oob_blocked_sum(jnp.zeros((64,), jnp.float32))
    findings = []
    for launch in launches:
        findings += bounds.check_launch(launch)
    return findings


# ---------------------------------------------------------------------------
# REPRO-J001: O(N^2) residual
# ---------------------------------------------------------------------------

def quadratic_residual_fwd(q, k, v):
    """A fwd rule that saves the full attention matrix as a residual —
    the exact memory blow-up the paper's chunked recurrence avoids."""
    att = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    att = jnp.where(lax.broadcasted_iota(jnp.int32, att.shape, 0)
                    >= lax.broadcasted_iota(jnp.int32, att.shape, 1),
                    att, 0.0)
    o = jnp.dot(att, v, preferred_element_type=jnp.float32)
    return o, (q, k, v, att)


def audit_quadratic_residual_fixture() -> list[Finding]:
    def make_args(n):
        d = 16
        sds = jax.ShapeDtypeStruct
        return (sds((n, d), jnp.float32),) * 3
    return jaxpr_audit.residual_growth_findings(
        quadratic_residual_fwd, make_args,
        "fixtures.quadratic_residual_fwd")


# ---------------------------------------------------------------------------
# REPRO-J002: unguarded bf16 accumulation
# ---------------------------------------------------------------------------

def unguarded_bf16_matmul(a, b):
    """bf16 x bf16 contraction accumulating in bf16 (no
    preferred_element_type) — loses ~8 bits of mantissa per add."""
    return lax.dot(a, b)


def audit_bf16_fixture() -> list[Finding]:
    sds = jax.ShapeDtypeStruct
    args = (sds((32, 32), jnp.bfloat16), sds((32, 32), jnp.bfloat16))
    return jaxpr_audit.precision_findings(
        unguarded_bf16_matmul, args, "fixtures.unguarded_bf16_matmul")
