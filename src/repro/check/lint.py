"""Custom AST lint for repo-specific invariants ruff cannot express.

Single-file, stdlib-`ast` based, no execution of the linted code.

  REPRO-L001  `time.time` / `time.perf_counter` (call, reference, or
              from-import) anywhere but `tune/timer.py`.  All timing
              flows through `repro.tune.timer` (`measure` for kernel
              benchmarking with its block_until_ready discipline,
              `now`/`wallclock` for coarse spans and metadata stamps)
              so a grep for timer imports finds every clock in the
              repo and no ad-hoc benchmark bypasses device sync.
  REPRO-L002  integer-literal tile constants in `kernels/*.py` outside
              `defaults.py`: parameter defaults or module constants
              named like chunk/block_q/block_k/pages_per_block must be
              sourced from `kernels.defaults.DEFAULT_TILES` — a stray
              literal silently escapes both the defaults table and the
              autotuner.
  REPRO-L003  `interpret=True` as a parameter default or a literal
              keyword argument in non-test code.  Interpret mode is a
              CPU validation device for tests/CI; production dispatch
              selects it via the impl name ("pallas_interpret"), never
              a hardcoded flag.
  REPRO-L004  ad-hoc latency math inside `serve/` or `obs/` outside
              `obs/metrics.py`: any `time.*` clock, an
              `np/numpy/statistics` percentile / quantile / median
              call (or from-import), or `sorted(...)[...]` rank
              indexing.  The serving stack has exactly one clock
              (`repro.tune.timer.now`) and one home for percentile
              math (`repro.obs.metrics.percentiles` / `Histogram`) —
              a second implementation drifts from the histogram's
              inverted-CDF convention and silently disagrees with the
              exported metrics.  `time.*` in serve/ fires L001 AND
              L004 by design: one is the repo-wide timer rule, the
              other the serving-observability contract.

Suppression: a line ending in `# repro: ignore[RULE]` is exempt from
RULE (use sparingly; the docs require a justification comment).
"""
from __future__ import annotations

import ast
import os
import re

from repro.check.findings import Finding

LINT_ROOTS = ("src", "benchmarks", "examples")
TIMER_HOME = os.path.join("tune", "timer.py")
METRICS_HOME = os.path.join("obs", "metrics.py")
_TIME_ATTRS = {"time", "perf_counter"}
# L004: every clock the time module offers, not just the two L001 bans
_CLOCK_ATTRS = {"time", "perf_counter", "monotonic", "process_time",
                "thread_time", "time_ns", "perf_counter_ns",
                "monotonic_ns"}
_PCT_MODULES = {"np", "numpy", "statistics"}
_PCT_ATTRS = {"percentile", "nanpercentile", "quantile", "nanquantile",
              "quantiles", "median", "nanmedian"}
_TILE_NAME = re.compile(
    r"(^|_)(chunk|block_q|block_k|blk|bq|bk|pages_per_block|ppb)($|_)"
    r"|(^|_)(chunk|block)s?$",
    re.IGNORECASE)
_SUPPRESS = re.compile(r"#\s*repro:\s*ignore\[([A-Z0-9-]+)\]")


def _is_test_path(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return any(p in ("tests", "conftest.py") or p.startswith("test_")
               for p in parts)


def _suppressed(source_lines: list[str], lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(source_lines):
        m = _SUPPRESS.search(source_lines[lineno - 1])
        return bool(m) and m.group(1) in (rule, rule.split("-")[-1])
    return False


class _FileLint(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[Finding] = []
        self.in_kernels = (os.sep + "kernels" + os.sep in path
                           and not path.endswith("defaults.py"))
        self.is_timer = path.endswith(TIMER_HOME)
        self.is_test = _is_test_path(path)
        norm = os.path.normpath(path)
        self.in_serving = ((os.sep + "serve" + os.sep in norm
                            or os.sep + "obs" + os.sep in norm)
                           and not norm.endswith(METRICS_HOME)
                           and not self.is_test)
        # names bound by `import time as X` in this file
        self.time_aliases: set[str] = set()

    def _emit(self, rule: str, node: ast.AST, detail: str):
        lineno = getattr(node, "lineno", 0)
        if _suppressed(self.lines, lineno, rule):
            return
        self.findings.append(
            Finding(rule, f"{self.path}:{lineno}", detail))

    # -- L001 ---------------------------------------------------------------
    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            if alias.name == "time":
                self.time_aliases.add(alias.asname or "time")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module == "time" and not self.is_timer:
            for alias in node.names:
                if alias.name in _TIME_ATTRS:
                    self._emit("REPRO-L001", node,
                               f"from time import {alias.name}; use "
                               f"repro.tune.timer instead")
                if self.in_serving and alias.name in _CLOCK_ATTRS:
                    self._emit("REPRO-L004", node,
                               f"from time import {alias.name} in the "
                               f"serving stack; stamp with "
                               f"repro.tune.timer.now")
        if self.in_serving and node.module in ("numpy", "statistics"):
            for alias in node.names:
                if alias.name in _PCT_ATTRS:
                    self._emit("REPRO-L004", node,
                               f"from {node.module} import "
                               f"{alias.name}; percentile math lives "
                               f"in repro.obs.metrics")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if (not self.is_timer and node.attr in _TIME_ATTRS
                and isinstance(node.value, ast.Name)
                and node.value.id in self.time_aliases):
            self._emit("REPRO-L001", node,
                       f"{node.value.id}.{node.attr}; use "
                       f"repro.tune.timer (measure/now/wallclock)")
        if self.in_serving and isinstance(node.value, ast.Name):
            if (node.value.id in self.time_aliases
                    and node.attr in _CLOCK_ATTRS):
                self._emit("REPRO-L004", node,
                           f"{node.value.id}.{node.attr} in the "
                           f"serving stack; stamp with "
                           f"repro.tune.timer.now")
            elif (node.value.id in _PCT_MODULES
                    and node.attr in _PCT_ATTRS):
                self._emit("REPRO-L004", node,
                           f"{node.value.id}.{node.attr} in the "
                           f"serving stack; percentile math lives in "
                           f"repro.obs.metrics (percentiles/Histogram)")
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript):
        # L004: sorted(...)[...] — hand-rolled rank/percentile indexing
        if (self.in_serving and isinstance(node.value, ast.Call)
                and isinstance(node.value.func, ast.Name)
                and node.value.func.id == "sorted"):
            self._emit("REPRO-L004", node,
                       "sorted(...)[...] rank indexing in the serving "
                       "stack; use repro.obs.metrics.percentiles")
        self.generic_visit(node)

    # -- L002 / L003 --------------------------------------------------------
    def _check_defaults(self, node):
        posargs = node.args.posonlyargs + node.args.args
        defaults = node.args.defaults
        pairs = list(zip(posargs[len(posargs) - len(defaults):], defaults))
        pairs += [(a, d) for a, d in
                  zip(node.args.kwonlyargs, node.args.kw_defaults)
                  if d is not None]
        for arg, default in pairs:
            if (self.in_kernels and _TILE_NAME.search(arg.arg)
                    and isinstance(default, ast.Constant)
                    and type(default.value) is int):
                self._emit("REPRO-L002", default,
                           f"parameter {arg.arg}={default.value} "
                           f"hardcodes a tile; source it from "
                           f"kernels.defaults.DEFAULT_TILES")
            if (not self.is_test and arg.arg == "interpret"
                    and isinstance(default, ast.Constant)
                    and default.value is True):
                self._emit("REPRO-L003", default,
                           f"def {node.name}(..., interpret=True) in "
                           f"non-test code")

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef):
        self._check_defaults(node)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign):
        if (self.in_kernels and isinstance(node.value, ast.Constant)
                and type(node.value.value) is int):
            for target in node.targets:
                if (isinstance(target, ast.Name)
                        and _TILE_NAME.search(target.id)):
                    self._emit("REPRO-L002", node,
                               f"{target.id} = {node.value.value} "
                               f"hardcodes a tile constant; import it "
                               f"from kernels/defaults.py")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if not self.is_test:
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    self._emit("REPRO-L003", kw.value,
                               "interpret=True literal in non-test "
                               "code; select the pallas_interpret impl "
                               "by name instead")
        self.generic_visit(node)


def lint_file(path: str, source: str | None = None) -> list[Finding]:
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("REPRO-L001", f"{path}:{e.lineno or 0}",
                        f"unparseable file: {e.msg}")]
    lint = _FileLint(path, source)
    lint.visit(tree)
    return lint.findings


def iter_source_files(root: str = ".") -> list[str]:
    files = []
    for base in LINT_ROOTS:
        top = os.path.join(root, base)
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    files.append(os.path.join(dirpath, fn))
    return files


def run(root: str = ".", log=lambda s: None
        ) -> tuple[list[Finding], list[dict]]:
    findings: list[Finding] = []
    files = iter_source_files(root)
    for path in files:
        if _is_test_path(path):
            continue
        findings += lint_file(path)
    log(f"check,lint,{'FAIL' if findings else 'ok'} "
        f"({len(files)} files)")
    return findings, [{"pass": "lint", "files": len(files),
                       "roots": list(LINT_ROOTS)}]
