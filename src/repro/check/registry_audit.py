"""Registry coverage + consistency audits.

  REPRO-R001  every kernel family exposes the full impl set
              {xla, pallas, pallas_interpret, ref} — the interpret impl
              is how CI validates the pallas kernel on CPU, and the
              ref oracle is what both are validated against, so a
              family missing one has an unverifiable cell.
  REPRO-R002  every mixer backend's capability flags match the methods
              it actually overrides: a backend claiming
              `supports_noncausal` without overriding `apply_noncausal`
              dispatches to the base NotImplementedError at runtime
              (and the inverse silently hides a working path from
              encoder/cross-attention model selection).
  REPRO-R003  a softmax-family impl registering a `bwd` must also
              register the `fwd_res` that produces its residuals —
              `ops.softmax_attention`'s custom VJP calls fwd_res for
              any impl it will later call bwd on.
"""
from __future__ import annotations

from repro.check.findings import Finding
from repro.kernels import ops
from repro.mixers import base as mixer_base

FAMILIES = ("linear", "softmax", "gla", "ssd", "paged",
            "linear_decode_fused", "gla_decode_fused",
            "softmax_decode_fused", "paged_decode_fused")
REQUIRED_IMPLS = ("xla", "pallas", "pallas_interpret", "ref")

# (flag, methods that must be overridden iff the flag is set)
_CAPABILITIES = (
    ("supports_noncausal", ("apply_noncausal",)),
    ("supports_cross_decode", ("cross_precompute", "cross_decode")),
)


def check_kernel_registry() -> list[Finding]:
    findings = []
    for family in FAMILIES:
        names = set(ops.kernel_names(family))
        for impl in REQUIRED_IMPLS:
            if impl not in names:
                findings.append(Finding(
                    "REPRO-R001", f"kernels/ops.py[{family}]",
                    f"family registers {sorted(names)} but not "
                    f"{impl!r}"))
        if family == "softmax":
            for name in names:
                impl = ops.get_kernel(family, name)
                if impl.bwd is not None and impl.fwd_res is None:
                    findings.append(Finding(
                        "REPRO-R003",
                        f"kernels/ops.py[{family}.{name}]",
                        "bwd registered without fwd_res; the custom "
                        "VJP cannot produce this impl's residuals"))
    return findings


def _overrides(backend, method: str) -> bool:
    base_fn = getattr(mixer_base.AttentionBackend, method)
    return getattr(type(backend), method, base_fn) is not base_fn


def check_mixer_flags() -> list[Finding]:
    findings = []
    for name, backend in sorted(mixer_base._BACKENDS.items()):
        for flag, methods in _CAPABILITIES:
            claimed = bool(getattr(backend, flag))
            # a subclass may inherit the override from its parent
            # backend class while re-declaring the flag (mixers/gla.py
            # narrows GQAProjectionBackend); "overridden" therefore
            # means "not the AttentionBackend base stub"
            has = all(_overrides(backend, m) for m in methods)
            if claimed and not has:
                findings.append(Finding(
                    "REPRO-R002", f"mixers[{name}]",
                    f"{flag}=True but {methods} not overridden "
                    f"(would raise NotImplementedError at dispatch)"))
            elif has and not claimed and flag == "supports_cross_decode":
                findings.append(Finding(
                    "REPRO-R002", f"mixers[{name}]",
                    f"{flag}=False but {methods} are implemented "
                    f"(working path hidden from model selection)"))
    return findings


def run(log=lambda s: None) -> tuple[list[Finding], list[dict]]:
    findings = check_kernel_registry() + check_mixer_flags()
    coverage = [{"pass": "registry", "families": list(FAMILIES),
                 "required_impls": list(REQUIRED_IMPLS),
                 "mixers": sorted(mixer_base._BACKENDS)}]
    log(f"check,registry,{'FAIL' if findings else 'ok'} "
        f"({len(FAMILIES)} families, "
        f"{len(mixer_base._BACKENDS)} mixers)")
    return findings, coverage
