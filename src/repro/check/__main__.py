"""CLI: run every analyzer pass, print findings, emit CHECK.json.

    python -m repro.check [--strict] [--json artifacts/CHECK.json]
                          [--only jaxpr,bounds,vmem,registry,lint]

Exit status: 0 when clean (always, without --strict); --strict exits 1
on any finding — the CI static-analysis job runs that mode.  CHECK.json
carries the full findings list plus the coverage records proving every
(family, impl) was audited.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from repro.check import bounds, jaxpr_audit, lint, registry_audit, vmem
from repro.check.findings import RULES

PASSES = {
    "registry": lambda log: registry_audit.run(log=log),
    "lint": lambda log: lint.run(log=log),
    "vmem": lambda log: vmem.run(log=log),
    "jaxpr": lambda log: jaxpr_audit.run(log=log),
    "bounds": lambda log: bounds.run(log=log),
}


def run_all(only=None, log=print) -> dict:
    findings, coverage = [], []
    for name, runner in PASSES.items():
        if only and name not in only:
            continue
        f, c = runner(log)
        findings += f
        coverage += [{**rec, "pass": rec.get("pass", name)} for rec in c]
    counts: dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "version": 1,
        "device": jax.default_backend(),
        "passes": sorted(only) if only else sorted(PASSES),
        "rules": {rid: RULES[rid] for rid in sorted(RULES)},
        "coverage": coverage,
        "findings": [f.to_json() for f in findings],
        "counts": dict(sorted(counts.items())),
        "clean": not findings,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="static kernel-contract analyzer")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding (the CI gate)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the CHECK.json report here")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of passes "
                         f"({','.join(PASSES)})")
    args = ap.parse_args(argv)

    only = None
    if args.only:
        only = {p.strip() for p in args.only.split(",") if p.strip()}
        unknown = only - set(PASSES)
        if unknown:
            ap.error(f"unknown pass(es) {sorted(unknown)}; "
                     f"known: {sorted(PASSES)}")

    report = run_all(only=only)
    for f in report["findings"]:
        print(f"{f['rule']} {f['where']}: {f['detail']}")
    audited = [c for c in report["coverage"] if "impl" in c]
    print(f"check,done,{len(report['findings'])} findings,"
          f"{len(audited)} (family,impl) cells audited")

    if args.json:
        parent = os.path.dirname(args.json)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"check,report,{args.json}")

    if args.strict and report["findings"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
