"""repro.check — static kernel-contract analyzer (docs/static_analysis.md).

Audits every registered kernel without executing one:

  jaxpr_audit     J-rules: O(ND) residuals, f32 accumulation, dtype
                  closure (abstract tracing over the ops.py registry)
  bounds          B-rules: BlockSpec/grid proofs for every Pallas
                  launch (index maps, scalar-prefetch gathers, tails)
  vmem            V-rules: default + cached tiles vs the VMEM budget
                  for every configs/registry.py workload
  registry_audit  R-rules: impl-set completeness, mixer capability
                  flags, softmax custom-VJP wiring
  lint            L-rules: AST lint for repo invariants (timer
                  discipline, no stray tile literals, no interpret=True)

CLI: `python -m repro.check [--strict] [--json artifacts/CHECK.json]`.
"""
from repro.check.findings import RULES, Finding  # noqa: F401
