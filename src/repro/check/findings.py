"""Finding records + the rule catalog for `repro.check`.

Every analyzer pass emits `Finding`s tagged with a stable rule ID; the
CLI aggregates them into CHECK.json and CI fails on any finding under
`--strict`.  Rule IDs are append-only: retiring a rule leaves its ID
reserved (docs/static_analysis.md is the human-readable catalog).

Prefixes group the passes:

  REPRO-J*  jaxpr audits          (check/jaxpr_audit.py)
  REPRO-B*  BlockSpec/grid bounds (check/bounds.py)
  REPRO-V*  VMEM tile legality    (check/vmem.py)
  REPRO-R*  registry coverage     (check/registry_audit.py)
  REPRO-L*  AST lint              (check/lint.py)
"""
from __future__ import annotations

import dataclasses

RULES: dict[str, str] = {
    # -- jaxpr audits ------------------------------------------------------
    "REPRO-J001": "custom-VJP residual bytes grow superlinearly in N "
                  "(the paper's memory story requires O(ND) residuals)",
    "REPRO-J002": "low-precision dot_general without "
                  "preferred_element_type=float32 (unguarded bf16/f16 "
                  "accumulation)",
    "REPRO-J003": "kernel output dtype does not close over the input "
                  "dtype (f32 leak or silent downcast)",
    # -- BlockSpec / grid bounds ------------------------------------------
    "REPRO-B001": "BlockSpec index_map result out of the array's extent "
                  "at some grid point (incl. scalar-prefetch gathers)",
    "REPRO-B002": "grid does not cover the full output extent "
                  "(dropped tail blocks)",
    "REPRO-B003": "block shape does not divide the (padded) array "
                  "extent (partial blocks)",
    "REPRO-B004": "per-grid-step VMEM footprint (streamed blocks + "
                  "scratch) exceeds the budget",
    # -- VMEM tile legality -----------------------------------------------
    "REPRO-V001": "default tile (kernels/defaults.py) fails the VMEM "
                  "estimate for a registry shape",
    "REPRO-V002": "tuning-cache entry is invalid or its tiles fail the "
                  "VMEM estimate for its shape bucket",
    # -- registry coverage ------------------------------------------------
    "REPRO-R001": "kernel family missing a required impl "
                  "(xla/pallas/pallas_interpret/ref)",
    "REPRO-R002": "mixer capability flag inconsistent with the methods "
                  "the backend actually overrides",
    "REPRO-R003": "softmax-family impl registers a bwd without the "
                  "fwd_res the custom VJP needs",
    # -- AST lint ----------------------------------------------------------
    "REPRO-L001": "time.time/time.perf_counter outside tune/timer.py "
                  "(use repro.tune.timer.measure/now/wallclock)",
    "REPRO-L002": "hardcoded tile constant in kernels/*.py outside "
                  "defaults.py (chunk/block_q/block_k/pages_per_block)",
    "REPRO-L003": "interpret=True default or literal in non-test code "
                  "(interpret mode is a test/CI validation device)",
    "REPRO-L004": "ad-hoc latency math in serve/ or obs/ outside "
                  "obs/metrics.py: time.* clocks, np/statistics "
                  "percentile/quantile/median calls, or sorted(...)[...] "
                  "rank indexing (timestamps come from repro.tune.timer, "
                  "percentiles from repro.obs.metrics)",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit: a stable rule ID, where it fired, and why."""

    rule: str
    where: str   # "family.impl.op @ shape" or "path/to/file.py:LINE"
    detail: str

    def __post_init__(self):
        if self.rule not in RULES:
            raise KeyError(f"unknown rule id {self.rule!r}; known: "
                           f"{sorted(RULES)}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "where": self.where,
                "detail": self.detail, "summary": RULES[self.rule]}

    def __str__(self) -> str:
        return f"{self.rule} {self.where}: {self.detail}"
