"""Jaxpr audits: residual growth, f32 accumulation, dtype closure.

Everything here is abstract — `jax.eval_shape` / `jax.make_jaxpr` trace
the REAL registry entry points (kernels/ops.py) over representative
shapes without executing a single kernel, so the audits are cheap
enough to run on every PR and cover the compiled-pallas impls even on a
CPU container.

Three contracts, one rule each:

  REPRO-J001  custom-VJP residuals are O(ND): the residual pytree the
              `_<family>_causal_fwd` rule saves is measured at two
              sequence lengths and its byte growth must track N, not
              N^2 (the paper's memory story; autodiff's O(N D^2) chunk
              stacks or an accidental (N, N) residual both trip this).
  REPRO-J002  every `dot_general` whose operands are bf16/f16 carries
              `preferred_element_type=float32` — the MXU must
              accumulate in f32.  Kernels that upcast operands before
              the dot satisfy the contract trivially.
  REPRO-J003  the primary output dtype equals the query dtype for every
              (family, impl, dtype) cell — no f32 leaks into the
              residual stream, no silent downcasts.

Representative shapes are drawn around the `tune.space` tile extents
(so clamped and multi-tile paths both trace) plus odd-N / GQA / bf16
edge cases.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.check.findings import Finding
from repro.kernels import ops
from repro.tune.sweep import build_problem

F32 = jnp.float32
LOW_PRECISION = (jnp.bfloat16, jnp.float16)

# residual-growth measurement points: both multiples of every default
# tile AND spanning the tune.space chunk extents, so the ratio isolates
# the N-dependence (N2/N1 == 4; O(ND) residuals give exactly 4)
_RES_N = (128, 512)
# slack over perfectly-linear growth: constant-size leaves (states,
# scalars) pull the ratio DOWN, so anything meaningfully above the
# linear ratio means a superlinear leaf snuck into the residuals
_RES_SLACK = 1.5

# audit shapes: (tag, shape-dict overrides).  Base is MHA at a tile
# boundary; variants clamp tiles (odd N below the default), exercise
# GQA index maps, and cross a tile boundary with a ragged tail.
_BASE = {"b": 2, "h": 4, "hkv": 4, "n": 128, "d": 16}
AUDIT_SHAPES = [
    ("base", {}),
    ("gqa", {"hkv": 2}),
    ("odd_n", {"n": 97}),
    ("tail_n", {"n": 257, "hkv": 2}),
]
AUDIT_DTYPES = (jnp.float32, jnp.bfloat16)

# the custom-VJP forward rules (residual-saving halves) per family.
# softmax only routes through its rule for impls that registered a bwd;
# the others fall back to autodiff and have no residual contract.
_FWD_RULES = {
    "linear": lambda impl: (lambda q, k, v:
                            ops._la_causal_fwd(q, k, v, 1.0, 1.0, 64,
                                               impl)),
    "gla": lambda impl: (lambda q, k, v, ld:
                         ops._gla_causal_fwd(q, k, v, ld, 1.0, 1.0, 64,
                                             impl)),
    "ssd": lambda impl: (lambda q, k, v, ld:
                         ops._ssd_causal_fwd(q, k, v, ld, 64, impl)),
    "softmax": lambda impl: (lambda q, k, v:
                             ops._softmax_causal_fwd(q, k, v, 64, impl)),
}


def _shape_at(tag_overrides: dict, **extra) -> dict:
    shape = dict(_BASE)
    shape.update(tag_overrides)
    shape.update(extra)
    return shape


def _tree_bytes(tree) -> int:
    return sum(math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def _abstract_args(family: str, impl: str, shape: dict, op: str,
                   dtype) -> tuple:
    """(callable, example args) for one registry cell — reuses the
    sweep driver's problem builder so the audit traces exactly what the
    autotuner measures (the production dispatch path)."""
    return build_problem(family, impl, shape, op, dtype=dtype)


def residual_supports_bwd(family: str, impl_name: str) -> bool:
    """Does (family, impl) train through a custom-VJP residual path?"""
    impl = ops.get_kernel(family, impl_name)
    if family == "softmax":
        return impl.bwd is not None and impl.fwd_res is not None
    return family in _FWD_RULES  # linear/gla/ssd: bwd falls back to xla


def residual_growth_findings(fwd_rule, make_args, where: str,
                             ns=_RES_N,
                             slack: float = _RES_SLACK) -> list[Finding]:
    """REPRO-J001 core: `fwd_rule(*make_args(n)) -> (out, residuals)`;
    residual bytes across the two Ns must grow ~linearly."""
    measured = []
    for n in ns:
        args = make_args(n)
        res = jax.eval_shape(lambda *a: fwd_rule(*a)[1], *args)
        measured.append((n, _tree_bytes(res)))
    (n1, b1), (n2, b2) = measured
    if b1 <= 0:
        return [Finding("REPRO-J001", where,
                        "empty residual pytree (nothing for the "
                        "backward to read)")]
    ratio, linear = b2 / b1, n2 / n1
    if ratio > slack * linear:
        return [Finding(
            "REPRO-J001", where,
            f"residual bytes grew {ratio:.1f}x when N grew {linear:.0f}x "
            f"({b1} B @ N={n1} -> {b2} B @ N={n2}); O(ND) residuals "
            f"must track N")]
    return []


def audit_residuals(family: str, impl: str,
                    dtype=jnp.float32) -> list[Finding]:
    """REPRO-J001: residual bytes must grow ~linearly in N."""
    if family not in _FWD_RULES or not residual_supports_bwd(family, impl):
        return []
    rule = _FWD_RULES[family](impl)

    def make_args(n):
        _, args = _abstract_args(family, impl, _shape_at({}, n=n),
                                 "fwd", dtype)
        return args
    return residual_growth_findings(rule, make_args,
                                    f"{family}.{impl}.fwd")


def _iter_eqns(jaxpr):
    """Yield every eqn in a jaxpr and all jaxprs nested in its params
    (scan/pjit/custom_vjp bodies, pallas_call kernel jaxprs, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from _iter_eqns(sub)


def _sub_jaxprs(obj):
    if isinstance(obj, jax.core.Jaxpr):
        yield obj
    elif isinstance(obj, jax.core.ClosedJaxpr):
        yield obj.jaxpr
    elif isinstance(obj, dict):
        for v in obj.values():
            yield from _sub_jaxprs(v)
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            yield from _sub_jaxprs(v)


def _is_low_precision(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and any(dtype == jnp.dtype(lp)
                                     for lp in LOW_PRECISION)


def precision_findings(fn, args, where: str) -> list[Finding]:
    """REPRO-J002 core: trace `fn(*args)` and flag every low-precision
    dot_general that does not request f32 accumulation (first hit only
    — one finding per traced callable is enough signal)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in _iter_eqns(jaxpr.jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        if not any(_is_low_precision(v.aval) for v in eqn.invars):
            continue
        pref = eqn.params.get("preferred_element_type")
        if pref is None or jnp.dtype(pref) not in (jnp.dtype(jnp.float32),
                                                   jnp.dtype(jnp.float64)):
            operand_dtypes = [str(getattr(v.aval, "dtype", "?"))
                              for v in eqn.invars]
            return [Finding(
                "REPRO-J002", where,
                f"dot_general({' x '.join(operand_dtypes)}) with "
                f"preferred_element_type={pref!r}; low-precision MXU "
                f"inputs must accumulate in f32")]
    return []


def audit_precision(family: str, impl: str, shape: dict, op: str,
                    dtype=jnp.bfloat16) -> list[Finding]:
    """REPRO-J002: trace with low-precision inputs; every dot_general
    fed a bf16/f16 operand must request f32 accumulation."""
    try:
        fn, args = _abstract_args(family, impl, shape, op, dtype)
    except ValueError:
        return []  # op not supported for this family (paged bwd)
    return precision_findings(
        fn, args, f"{family}.{impl}.{op} @ {_fmt_shape(shape)}")


def audit_dtype_closure(family: str, impl: str, shape: dict,
                        dtype) -> list[Finding]:
    """REPRO-J003: the primary output must come back in the input dtype."""
    fn, args = _abstract_args(family, impl, shape, "fwd", dtype)
    out = jax.eval_shape(fn, *args)
    primary = jax.tree_util.tree_leaves(out)[0]
    if jnp.dtype(primary.dtype) != jnp.dtype(dtype):
        return [Finding(
            "REPRO-J003", f"{family}.{impl}.fwd @ {_fmt_shape(shape)}",
            f"input dtype {jnp.dtype(dtype).name} -> output dtype "
            f"{jnp.dtype(primary.dtype).name}")]
    return []


def _fmt_shape(shape: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(shape.items()))


def _family_shape(family: str, overrides: dict) -> dict:
    shape = _shape_at(overrides)
    if family in ("paged", "paged_decode_fused"):
        shape["page_size"] = 16
    return shape


def audit_family(family: str, impl: str, log=lambda s: None
                 ) -> tuple[list[Finding], dict]:
    """All jaxpr audits for one (family, impl).  Returns (findings,
    coverage record)."""
    findings: list[Finding] = []
    audited_ops = ["fwd"]
    trains = residual_supports_bwd(family, impl)
    if trains:
        audited_ops.append("fwdbwd")
        findings += audit_residuals(family, impl)
    for tag, overrides in AUDIT_SHAPES:
        shape = _family_shape(family, overrides)
        for dtype in AUDIT_DTYPES:
            findings += audit_dtype_closure(family, impl, shape, dtype)
        findings += audit_precision(family, impl, shape, "fwd")
        if trains:
            findings += audit_precision(family, impl, shape, "fwdbwd")
    log(f"check,jaxpr,{family}.{impl},"
        f"{'FAIL' if findings else 'ok'}")
    coverage = {"family": family, "impl": impl, "ops": audited_ops,
                "shapes": [tag for tag, _ in AUDIT_SHAPES],
                "dtypes": [jnp.dtype(d).name for d in AUDIT_DTYPES]}
    return findings, coverage


def run(log=lambda s: None) -> tuple[list[Finding], list[dict]]:
    """Audit every registered (family, impl) of the five kernel
    families.  Returns (findings, coverage list)."""
    findings: list[Finding] = []
    coverage: list[dict] = []
    for family in ("linear", "softmax", "gla", "ssd", "paged",
                   "linear_decode_fused", "gla_decode_fused",
                   "softmax_decode_fused", "paged_decode_fused"):
        for impl in ops.kernel_names(family):
            f, c = audit_family(family, impl, log=log)
            findings += f
            coverage.append(c)
    return findings, coverage
