"""BlockSpec/grid bounds proofs for every Pallas kernel in the repo.

No kernel body ever executes.  A context manager swaps
`pallas.pallas_call` for an interposer that records the launch geometry
(grid, BlockSpecs, out_shape, scratch, scalar-prefetch operands) and
returns zeros of `out_shape`; the real module-level entry points
(flash/linear/gla/ssd/paged) then run eagerly over adversarial driver
shapes — odd N, GQA groups, continuation `q_offset`, ragged per-slot
lengths including 0, page tables with a sink page — and every recorded
launch is checked exhaustively:

  REPRO-B001  every index_map result at every grid point stays inside
              the operand's extent.  Scalar-prefetch operands are
              handed to the index maps as NUMPY arrays, so a gather
              like `page_table[b, pi]` that walks off the table raises
              instead of silently clamping the way jnp would — the
              per-slot frontier clamps in the repo's index maps are
              exactly what this proves necessary.
  REPRO-B002  the union of output block indices over the grid covers
              every block of the output (no dropped tail).
  REPRO-B003  block shapes divide the (padded) extents — Pallas pads
              partial blocks with garbage the kernels never mask.
  REPRO-B004  the per-grid-step working set (double-buffered streamed
              blocks + scratch) fits the VMEM budget.

Grid-point enumeration is exhaustive, which is why the driver shapes
are small; the geometry being proved (clamp frontiers, `// group` GQA
reads, reversed scans, `pages_per_block` tails) is shape-independent.
"""
from __future__ import annotations

import contextlib
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas

from repro.check.findings import Finding
from repro.tune.space import VMEM_BUDGET


class PallasLaunch:
    """One recorded `pallas_call` launch: geometry + concrete operands."""

    def __init__(self, name, grid, in_specs, out_specs, out_shapes,
                 scratch, num_scalar_prefetch, scalar_args, operands):
        self.name = name
        self.grid = tuple(int(g) for g in grid)
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.out_shapes = out_shapes
        self.scratch = scratch
        self.num_scalar_prefetch = num_scalar_prefetch
        self.scalar_args = scalar_args
        self.operands = operands


def _kernel_name(fn) -> str:
    inner = getattr(fn, "func", fn)  # unwrap functools.partial
    return getattr(inner, "__name__", repr(fn))


def _as_list(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


@contextlib.contextmanager
def record_launches():
    """Patch `pallas.pallas_call` to record launches and fabricate
    zero outputs.  Kernel modules bind the module (`... import pallas
    as pl`), so one attribute swap intercepts every call site."""
    launches: list[PallasLaunch] = []
    real = pallas.pallas_call

    def fake_pallas_call(kernel, *, grid_spec=None, grid=None,
                         in_specs=None, out_specs=None, out_shape=None,
                         scratch_shapes=None, **_ignored):
        if grid_spec is not None:
            grid = grid_spec.grid
            in_specs = grid_spec.in_specs
            out_specs = grid_spec.out_specs
            scratch_shapes = getattr(grid_spec, "scratch_shapes", None)
            nsp = getattr(grid_spec, "num_scalar_prefetch", 0) or 0
        else:
            nsp = 0
        single_out = not isinstance(out_shape, (list, tuple))

        def run(*args):
            scalar_args = [np.asarray(a) for a in args[:nsp]]
            launches.append(PallasLaunch(
                _kernel_name(kernel), grid, _as_list(in_specs),
                _as_list(out_specs), _as_list(out_shape),
                _as_list(scratch_shapes), nsp, scalar_args,
                list(args[nsp:])))
            outs = [jnp.zeros(s.shape, s.dtype)
                    for s in _as_list(out_shape)]
            return outs[0] if single_out else outs
        return run

    pallas.pallas_call = fake_pallas_call
    try:
        yield launches
    finally:
        pallas.pallas_call = real


def _block_index(spec, point, scalar_args):
    idx = spec.index_map(*point, *scalar_args)
    idx = idx if isinstance(idx, tuple) else (idx,)
    return tuple(int(i) for i in idx)


def _check_spec(launch, role, spec, extents, dtype) -> list[Finding]:
    """B001/B003 (+ B002 coverage for outputs) for one BlockSpec."""
    findings = []
    where = f"{launch.name}[{role}]"
    block = tuple(int(b) for b in spec.block_shape)
    if len(block) != len(extents):
        return [Finding("REPRO-B001", where,
                        f"block rank {len(block)} != operand rank "
                        f"{len(extents)} {extents}")]
    for dim, (bs, ext) in enumerate(zip(block, extents)):
        if ext % bs:
            findings.append(Finding(
                "REPRO-B003", where,
                f"block_shape[{dim}]={bs} does not divide extent {ext} "
                f"(partial block would stream unmasked garbage)"))
    covered = set()
    for point in itertools.product(*map(range, launch.grid)):
        try:
            idx = _block_index(spec, point, launch.scalar_args)
        except IndexError as e:
            findings.append(Finding(
                "REPRO-B001", where,
                f"scalar-prefetch gather out of bounds at grid point "
                f"{point}: {e}"))
            break
        bad = [dim for dim, (i, bs, ext) in
               enumerate(zip(idx, block, extents))
               if i < 0 or (i + 1) * bs > ext]
        if bad:
            findings.append(Finding(
                "REPRO-B001", where,
                f"index_map{point} -> block {idx} exceeds extents "
                f"{extents} with block_shape {block} in dims {bad}"))
            break
        covered.add(idx)
    if role.startswith("out") and not findings:
        expected = math.prod(ext // bs for bs, ext in zip(block, extents))
        if len(covered) != expected:
            findings.append(Finding(
                "REPRO-B002", where,
                f"grid {launch.grid} writes {len(covered)} of "
                f"{expected} output blocks (dropped tail)"))
    return findings


def _nbytes(shape, dtype) -> int:
    return math.prod(shape) * jnp.dtype(dtype).itemsize


def check_launch(launch: PallasLaunch) -> list[Finding]:
    findings = []
    step_bytes = 0
    if len(launch.in_specs) != len(launch.operands):
        return [Finding("REPRO-B001", launch.name,
                        f"{len(launch.in_specs)} in_specs for "
                        f"{len(launch.operands)} operands")]
    for i, (spec, op) in enumerate(zip(launch.in_specs, launch.operands)):
        findings += _check_spec(launch, f"in{i}", spec, op.shape, op.dtype)
        step_bytes += _nbytes(spec.block_shape, op.dtype)
    for i, (spec, out) in enumerate(zip(launch.out_specs,
                                        launch.out_shapes)):
        findings += _check_spec(launch, f"out{i}", spec, out.shape,
                                out.dtype)
        step_bytes += _nbytes(spec.block_shape, out.dtype)
    scratch_bytes = sum(_nbytes(s.shape, s.dtype) for s in launch.scratch)
    # streamed blocks are double-buffered by the pipeline; scratch is not
    total = 2 * step_bytes + scratch_bytes
    if total > VMEM_BUDGET:
        findings.append(Finding(
            "REPRO-B004", launch.name,
            f"per-grid-step working set {total} B (2x{step_bytes} blocks"
            f" + {scratch_bytes} scratch) exceeds VMEM budget "
            f"{VMEM_BUDGET} B"))
    return findings


# ---------------------------------------------------------------------------
# Drivers: call the real entry points under the interposer
# ---------------------------------------------------------------------------

def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def _drive_flash():
    from repro.kernels import flash_attention as fa
    b, h, hkv, d = 2, 4, 2, 8
    # odd N with unequal blocks: exercises the lcm-padded backward grids
    n = 97
    q, o, do = (_rand(i, (b, h, n, d)) for i in range(3))
    k, v = (_rand(3 + i, (b, hkv, n, d)) for i in range(2))
    fa.flash_attention_pallas(q, k, v, block_q=16, block_k=32)
    lse = jnp.zeros((b, h, n), jnp.float32)
    fa.flash_attention_bwd_pallas(q, k, v, o, lse, do,
                                  block_q=16, block_k=32)
    # continuation prefill: short q window deep into a long KV cache,
    # per-slot offsets incl. 0 (fresh) and a frontier mid-cache
    nq, nk = 17, 97
    qc = _rand(5, (b, h, nq, d))
    off = jnp.array([0, nk - nq], jnp.int32)
    fa.flash_attention_pallas(qc, k, v, block_q=16, block_k=32,
                              q_offset=off)


def _drive_linear():
    from repro.kernels import linear_attention as la
    b, h, hkv, d, n = 2, 4, 2, 8, 50
    q = _rand(0, (b, h, n, d))
    k, v = (_rand(1 + i, (b, hkv, n, d)) for i in range(2))
    o, omega = (_rand(3 + i, (b, h, n, d)) for i in range(2))
    g = jnp.abs(_rand(5, (b, h, n))) + 1.0
    la.la_fwd_pallas(q, k, v, 1.0, 1.0, chunk=16)
    la.la_bwd_pallas(q, k, v, o, g, omega, 1.0, 1.0, chunk=16)


def _drive_gla():
    from repro.kernels import gla
    b, h, hkv, d, n = 2, 4, 2, 8, 50
    q = _rand(0, (b, h, n, d))
    k, v = (_rand(1 + i, (b, hkv, n, d)) for i in range(2))
    ld = -jnp.abs(_rand(3, (b, hkv, n))) * 0.1
    o, omega = (_rand(4 + i, (b, h, n, d)) for i in range(2))
    g = jnp.abs(_rand(6, (b, h, n))) + 1.0
    gla.gla_fwd_pallas(q, k, v, ld, 1.0, 1.0, chunk=16)
    gla.gla_bwd_pallas(q, k, v, ld, o, g, omega, 1.0, 1.0, chunk=16)


def _drive_ssd():
    from repro.kernels import ssd
    b, g, h, d, n = 2, 2, 4, 8, 50
    q, k = (_rand(i, (b, g, n, d)) for i in range(2))
    v, o, omega = (_rand(2 + i, (b, h, n, d)) for i in range(3))
    ld = -jnp.abs(_rand(5, (b, h, n))) * 0.1
    ssd.ssd_fwd_pallas(q, k, v, ld, chunk=16)
    ssd.ssd_bwd_pallas(q, k, v, ld, o, omega, chunk=16)


def _drive_paged():
    from repro.kernels import paged_attention as pa
    b, h, hkv, ps, d, pmax = 3, 4, 2, 8, 8, 5
    num_pages = b * pmax + 1  # + the engine's sink page (id 0)
    q = _rand(0, (b, h, 1, d))
    kp, vp = (_rand(1 + i, (num_pages, hkv, ps, d)) for i in range(2))
    pt = 1 + jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
    # ragged lengths: empty slot, mid-page tail, full allocation — the
    # frontier clamp must hold for all of them (and for the ppb tail:
    # pmax=5 with ppb=2 makes the last step's second page virtual)
    lens = jnp.array([0, 12, pmax * ps], jnp.int32)
    for ppb in (1, 2):
        pa.paged_attention_pallas(q, kp, vp, pt, lens,
                                  pages_per_block=ppb)


def _drive_linear_decode_fused():
    from repro.kernels import decode_fused as df
    b, h, hkv, d = 3, 4, 2, 8
    s = _rand(0, (b, hkv, d, d + 1))
    p = _rand(1, (b, hkv, d + 1))
    q = _rand(2, (b, h, d))
    k, v = (_rand(3 + i, (b, hkv, d)) for i in range(2))
    df.la_decode_fused_pallas(s, p, q, k, v, 1.0, 1.0)
    # MHA (group of 1) uses the same grid with g == h // hkv == 1
    df.la_decode_fused_pallas(s[:, :1], p[:, :1], q[:, :1], k[:, :1],
                              v[:, :1], 1.0, 1.0)


def _drive_gla_decode_fused():
    from repro.kernels import decode_fused as df
    b, h, hkv, d = 3, 4, 2, 8
    s = _rand(0, (b, hkv, d, d + 1))
    p = _rand(1, (b, hkv, d + 1))
    q = _rand(2, (b, h, d))
    k, v = (_rand(3 + i, (b, hkv, d)) for i in range(2))
    ld = -jnp.abs(_rand(5, (b, hkv))) * 0.1
    df.gla_decode_fused_pallas(s, p, q, k, v, ld, 1.0, 1.0)


def _drive_softmax_decode_fused():
    from repro.kernels import decode_fused as df
    b, h, hkv, d, n = 3, 4, 2, 8, 50
    q = _rand(0, (b, h, 1, d))
    k, v = (_rand(1 + i, (b, hkv, n, d)) for i in range(2))
    # ragged lengths incl. an empty slot; block_k both dividing the
    # padded extent and forcing a padded tail past the true S
    lens = jnp.array([0, 12, n], jnp.int32)
    for bk in (16, 32):
        df.softmax_decode_fused_pallas(q, k, v, lens, block_k=bk)


def _drive_paged_decode_fused():
    from repro.kernels import decode_fused as df
    b, h, hkv, ps, d, pmax = 3, 4, 2, 8, 8, 5
    num_pages = b * pmax + 1  # + the engine's sink page (id 0)
    q = _rand(0, (b, h, 1, d))
    kp, vp = (_rand(1 + i, (num_pages, hkv, ps, d)) for i in range(2))
    pt = 1 + jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
    lens = jnp.array([0, 12, pmax * ps], jnp.int32)
    for ppb in (1, 2):
        df.paged_decode_fused_pallas(q, kp, vp, pt, lens,
                                     pages_per_block=ppb)


DRIVERS = {
    "softmax": _drive_flash,
    "linear": _drive_linear,
    "gla": _drive_gla,
    "ssd": _drive_ssd,
    "paged": _drive_paged,
    "linear_decode_fused": _drive_linear_decode_fused,
    "gla_decode_fused": _drive_gla_decode_fused,
    "softmax_decode_fused": _drive_softmax_decode_fused,
    "paged_decode_fused": _drive_paged_decode_fused,
}


def check_entry(drive) -> tuple[list[Finding], list[str]]:
    """Run one driver under the interposer; prove every launch."""
    with record_launches() as launches:
        drive()
    findings = []
    for launch in launches:
        findings += check_launch(launch)
    return findings, [launch.name for launch in launches]


def run(log=lambda s: None) -> tuple[list[Finding], list[dict]]:
    findings: list[Finding] = []
    coverage: list[dict] = []
    for family, drive in DRIVERS.items():
        f, kernels = check_entry(drive)
        findings += f
        coverage.append({"family": family, "pass": "bounds",
                         "kernels": kernels})
        log(f"check,bounds,{family},"
            f"{'FAIL' if f else 'ok'} ({len(kernels)} launches)")
    return findings, coverage
