"""VMEM tile legality: defaults and tuning-cache entries vs the budget.

Two invariants, checked against `tune.space.vmem_bytes_estimate` (the
same structural model the sweep uses to reject candidates, so the
analyzer and the autotuner cannot disagree about legality):

  REPRO-V001  every default tile in `kernels/defaults.py` fits the
              VMEM budget for every (architecture x shape) cell in
              `configs/registry.py` — the untuned dispatch path must be
              launchable on every registered workload.
  REPRO-V002  every entry in a `TuningCache` file is structurally
              valid (`tune.cache.validate`) and its tiles fit the
              budget for the shape bucket they claim — a stale or
              hand-edited cache must fail CI, not a TPU lowering.

VMEM is a Pallas/TPU notion, so cache entries are budget-checked only
for pallas/pallas_interpret impls; xla entries (e.g. the softmax scan
chunk, whose working set scales with the full N) are schema-checked
only.  Default tiles are checked for every family — defaults apply to
the pallas path of each.
"""
from __future__ import annotations

import os

from repro.check.findings import Finding
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_config
from repro.kernels.defaults import DEFAULT_TILES
from repro.tune import cache as tcache
from repro.tune.space import VMEM_BUDGET, vmem_bytes_estimate

DEFAULT_CACHE_PATHS = (tcache.DEFAULT_CACHE_PATH,)


def registry_shapes() -> list[tuple[str, str, dict]]:
    """Every (arch, shape_name, shape-dict) cell the repo registers.

    Smoke configs keep this light (head counts and head_dim are the
    architectural facts the VMEM model reads; smoke presets preserve
    them scaled down only in depth/width, and full presets for the big
    archs need no weights here — only dims — so use full).
    """
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, sc in SHAPES.items():
            n = 1 if sc.kind == "decode" else sc.seq_len
            shape = {"b": sc.global_batch, "h": cfg.num_heads,
                     "hkv": cfg.num_kv_heads, "n": max(n, 1),
                     "d": cfg.resolved_head_dim}
            cells.append((arch, name, shape))
    return cells


def check_defaults(cells=None) -> list[Finding]:
    """REPRO-V001 over the full (family x arch x shape) product."""
    findings = []
    cells = registry_shapes() if cells is None else cells
    for family, tiles in DEFAULT_TILES.items():
        for arch, shape_name, shape in cells:
            fshape = dict(shape)
            if family in ("paged", "paged_decode_fused"):
                fshape["page_size"] = 16  # PagingCfg default
            est = vmem_bytes_estimate(family, tiles, fshape)
            if est > VMEM_BUDGET:
                findings.append(Finding(
                    "REPRO-V001",
                    f"kernels/defaults.py[{family}] @ {arch}/{shape_name}",
                    f"default tiles {tiles} need {est} B VMEM "
                    f"(> budget {VMEM_BUDGET} B) at shape {fshape}"))
    return findings


def _bucket_shape(bucket: str) -> dict:
    """Parse a `tune.cache.shape_bucket` string back into a shape dict."""
    shape = {}
    for part in bucket.split(","):
        key, _, val = part.partition("=")
        shape[key] = int(val)
    return shape


def check_cache_file(path: str) -> list[Finding]:
    """REPRO-V002 for one tuning-cache file (missing file = no entries)."""
    if not os.path.exists(path):
        return []
    try:
        cache = tcache.TuningCache.load(path)
    except (ValueError, OSError) as e:
        return [Finding("REPRO-V002", path, str(e))]
    findings = []
    for key, entry in cache.entries.items():
        if not entry["impl"].startswith("pallas"):
            continue  # VMEM budgets only constrain the pallas impls
        try:
            shape = _bucket_shape(entry["shape_bucket"])
            est = vmem_bytes_estimate(entry["family"], entry["tiles"],
                                      shape)
        except (KeyError, ValueError) as e:
            findings.append(Finding(
                "REPRO-V002", f"{path}[{key}]",
                f"unusable entry: {e!r}"))
            continue
        if est > VMEM_BUDGET:
            findings.append(Finding(
                "REPRO-V002", f"{path}[{key}]",
                f"cached tiles {entry['tiles']} need {est} B VMEM "
                f"(> budget {VMEM_BUDGET} B) for bucket "
                f"{entry['shape_bucket']}"))
    return findings


def run(cache_paths=DEFAULT_CACHE_PATHS, log=lambda s: None
        ) -> tuple[list[Finding], list[dict]]:
    cells = registry_shapes()
    findings = check_defaults(cells)
    log(f"check,vmem,defaults,{'FAIL' if findings else 'ok'} "
        f"({len(DEFAULT_TILES)} families x {len(cells)} cells)")
    for path in cache_paths:
        f = check_cache_file(path)
        findings += f
        log(f"check,vmem,cache:{path},{'FAIL' if f else 'ok'}")
    coverage = [{"pass": "vmem", "families": sorted(DEFAULT_TILES),
                 "cells": len(cells),
                 "caches": [p for p in cache_paths if os.path.exists(p)]}]
    return findings, coverage
