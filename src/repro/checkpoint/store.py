"""Sharded checkpointing: per-leaf .npy files + JSON manifest with
integrity hashes, async save thread, restore with arbitrary resharding.

Fault-tolerance contract:
  * save() writes leaves then the manifest LAST (atomic rename), so a
    crash mid-save never corrupts the previous checkpoint — restore
    always reads the newest complete manifest.
  * every leaf carries a sha256; restore verifies before use.
  * restore(shardings=...) device_puts each leaf with the NEW sharding,
    so a job can come back on a different mesh (elastic re-scale).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from repro.tune.timer import wallclock

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save(path: str, tree, step: int, *, blocking: bool = True):
    """Write `tree` under path/step_<step>/.  Returns the checkpoint dir."""
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    # pull to host before handing to the writer thread
    host_leaves = [np.asarray(x) for x in leaves]

    def write():
        manifest = {"step": step, "treedef": str(treedef),
                    "time": wallclock(), "leaves": []}
        for i, arr in enumerate(host_leaves):
            fn = _leaf_name(i)
            np.save(os.path.join(tmp_dir, fn), arr)
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            manifest["leaves"].append(
                {"file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype), "sha256": digest})
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp_dir, ckpt_dir)  # atomic publish

    if blocking:
        write()
        return ckpt_dir
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return ckpt_dir, t


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(path, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, tree_like, step: int | None = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of `tree_like` (values ignored).

    shardings: optional pytree of NamedShardings — leaves are device_put
    with the NEW sharding (elastic re-mesh support).
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(leaves_like)}")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (meta, sh) in enumerate(zip(manifest["leaves"], shard_leaves)):
        arr = np.load(os.path.join(ckpt_dir, meta["file"]))
        if verify:
            digest = hashlib.sha256(arr.tobytes()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checkpoint leaf {i} hash mismatch "
                              f"({meta['file']}) — corrupt checkpoint")
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
