"""Shared GQA projection machinery for the attention-shaped backends.

`GQAProjectionBackend` owns the wq/wk/wv/wo params, head split/merge and
rope application; the linear and softmax backends subclass it and only
differ in the score kernel + cache they run the projected heads through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import BATCH, MODEL, constrain
from repro.mixers.base import AttentionBackend
from repro.models.common import dense, dense_init
from repro.models.rope import apply_rope

F32 = jnp.float32


def split_heads(x, heads, hd):
    b, n, _ = x.shape
    return x.reshape(b, n, heads, hd).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, n, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * hd)


class GQAProjectionBackend(AttentionBackend):
    supports_noncausal = True

    def init(self, key, cfg, dtype=F32):
        hd = cfg.resolved_head_dim
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "wq": dense_init(k1, cfg.d_model, cfg.num_heads * hd,
                             bias=cfg.qkv_bias, dtype=dtype),
            "wk": dense_init(k2, cfg.d_model, cfg.num_kv_heads * hd,
                             bias=cfg.qkv_bias, dtype=dtype),
            "wv": dense_init(k3, cfg.d_model, cfg.num_kv_heads * hd,
                             bias=cfg.qkv_bias, dtype=dtype),
            "wo": dense_init(k4, cfg.num_heads * hd, cfg.d_model,
                             dtype=dtype),
        }

    def project_qkv(self, p, cfg, x, positions, compute_dtype,
                    rope: bool = True):
        hd = cfg.resolved_head_dim
        q = split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
        k = split_heads(dense(p["wk"], x, compute_dtype),
                        cfg.num_kv_heads, hd)
        v = split_heads(dense(p["wv"], x, compute_dtype),
                        cfg.num_kv_heads, hd)
        if rope and cfg.rope_kind not in ("none", "sinusoid"):
            q = apply_rope(q, positions, cfg.rope_kind, cfg.rope_fraction,
                           cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_kind, cfg.rope_fraction,
                           cfg.rope_theta, cfg.mrope_sections)
        q = constrain(q, BATCH, MODEL, None, None)
        k = constrain(k, BATCH, MODEL, None, None)
        v = constrain(v, BATCH, MODEL, None, None)
        return q, k, v

    def project_noncausal(self, p, cfg, x, ctx, positions, compute_dtype):
        """q from x, k/v from ctx (self-bidirectional or cross)."""
        hd = cfg.resolved_head_dim
        q = split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
        k = split_heads(dense(p["wk"], ctx, compute_dtype),
                        cfg.num_kv_heads, hd)
        v = split_heads(dense(p["wv"], ctx, compute_dtype),
                        cfg.num_kv_heads, hd)
        if positions is not None and cfg.rope_kind not in ("none",
                                                           "sinusoid"):
            q = apply_rope(q, positions, cfg.rope_kind, cfg.rope_fraction,
                           cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_kind, cfg.rope_fraction,
                           cfg.rope_theta, cfg.mrope_sections)
        return q, k, v

    def out(self, p, o_heads, compute_dtype):
        return dense(p["wo"], merge_heads(o_heads), compute_dtype)
