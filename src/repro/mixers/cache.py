"""Per-backend decode-cache types, in one namespace.

Every backend's `init_cache` returns one of these (or a pytree of them);
the serving engine scatters/gathers them purely as pytrees batched on
their leading batch dim, so it never needs to know which backend — or
cache shape — a model uses.

  LAState       linear / mla    O(Dk·Dv) recurrent state (paper's story)
  GLAState      gla             the same, decay-gated (core/gla.py)
  PagedGLAState gla (paged)     GLA states in a shared page arena — one
                                state page per slot (docs/paged_kv.md)
  KVCache       softmax         O(S) per layer key/value ring
  PagedKVCache  softmax (paged) fixed-size KV blocks + per-slot page table
  MambaCache    mamba2          SSD state + depthwise-conv window tail
  CrossState    linear cross    precomputed encoder-side LA state (whisper)
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.chunked import LAState, init_state
from repro.core.gla import GLAState, init_gla_state
from repro.core.ssd import SSDState, init_ssd_state

__all__ = ["LAState", "init_state", "GLAState", "init_gla_state",
           "PagedGLAState", "KVCache", "PagedKVCache", "MambaCache",
           "CrossState", "SSDState", "init_ssd_state"]


class KVCache(NamedTuple):
    """Softmax-backend decode cache: O(S) per layer."""

    k: jnp.ndarray  # (B, Hkv, S, hd)
    v: jnp.ndarray  # (B, Hkv, S, hd)


class PagedKVCache(NamedTuple):
    """Softmax-backend paged decode cache (cfg.paging; docs/paged_kv.md).

    The arenas are SHARED across slots — HBM is spent on pages actually
    written, not on batch x max_len worst case — and `page_table[b, i]`
    names the arena page holding slot b's tokens [i*ps, (i+1)*ps).
    Unallocated table entries point at the engine's reserved write-sink
    page (arena page num_pages - 1); attention masks by per-slot length,
    so whatever that page holds is never read into a live output.
    """

    k_pages: jnp.ndarray     # (num_pages, Hkv, page_size, hd)
    v_pages: jnp.ndarray     # (num_pages, Hkv, page_size, hd)
    page_table: jnp.ndarray  # (B, ceil(max_len / page_size)) int32


class PagedGLAState(NamedTuple):
    """GLA-backend paged decode cache (cfg.paging; docs/paged_kv.md).

    The first backend to exercise the page abstraction with a NON-KV
    state layout: a page holds one slot's whole (Hkv, Dk, Dv+1) decayed
    recurrent state — state pages, not KV-row pages — so every request
    needs exactly ONE page regardless of its token count (the paper's
    O(D^2) story, page-granular).  `page_table[b, 0]` names the arena
    page holding slot b's state; unassigned rows point at the engine's
    reserved write sink (arena page num_pages - 1), where retired slots
    keep decoding as batch padding without touching a live state.
    """

    s_pages: jnp.ndarray     # (num_pages, Hkv, Dk, Dv+1) f32
    p_pages: jnp.ndarray     # (num_pages, Hkv, Dv+1) f32
    page_table: jnp.ndarray  # (B, 1) int32


class MambaCache(NamedTuple):
    ssd: SSDState        # (B, H, state, hd)
    conv: jnp.ndarray    # (B, width-1, conv_ch) — last inputs of the window


class CrossState(NamedTuple):
    s: jnp.ndarray  # (B, Hkv, D, D+1) — precomputed sum_j k_j (x) [v_j, 1]
    p: jnp.ndarray  # (B, Hkv, D+1)
