"""The paper's linear-attention backend (normalized kernelized attention).

This IS the paper's contribution: f(x) = a + b x scores with the
prefix-sum factorization (core.linear_attention -> core.chunked /
kernels.linear_attention), l2-normalized q/k (Eq. 22), the analytic
O(N D) backward (kernels.ops), and an O(D^2) recurrent decode state
independent of context length.

Learnable coefficients (paper §2.2) live here too: when
cfg.la.learnable_coeffs is set, init adds scalar (a, b) params and apply
routes through the differentiable-coefficient entry point — no caller
ever branches on it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.linear_attention import la_attention, la_attention_decode, \
    la_attention_learnable, la_attention_prefill
from repro.core.numerics import l2_normalize
from repro.mixers.base import register_backend
from repro.mixers.cache import CrossState, init_state
from repro.mixers.qkv import GQAProjectionBackend, split_heads
from repro.models.common import dense

F32 = jnp.float32


@register_backend("linear")
class LinearAttentionBackend(GQAProjectionBackend):
    supports_cross_decode = True
    # decode routes through the fused single-kernel step family via
    # la_attention_decode (cfg.la.fused_decode; docs/fused_decode.md)
    supports_fused_decode = True

    def init(self, key, cfg, dtype=F32):
        p = super().init(key, cfg, dtype)
        if cfg.la.learnable_coeffs:
            # paper §2.2: f(x) = a + b x with learnable per-layer (a, b),
            # initialized at the Taylor coefficients of exp
            p["la_a"] = jnp.asarray(cfg.la.a, F32)
            p["la_b"] = jnp.asarray(cfg.la.b, F32)
        return p

    def apply(self, p, cfg, x, positions, compute_dtype=None):
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        if "la_a" in p:  # learnable coefficients (paper §2.2)
            o = la_attention_learnable(q, k, v, p["la_a"], p["la_b"], cfg.la)
        else:
            o = la_attention(q, k, v, cfg.la, causal=True)
        return self.out(p, o, compute_dtype)

    def apply_noncausal(self, p, cfg, x, ctx, positions=None,
                        compute_dtype=None):
        q, k, v = self.project_noncausal(p, cfg, x, ctx, positions,
                                         compute_dtype)
        o = la_attention(q, k, v, cfg.la, causal=False)
        return self.out(p, o, compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        # paper's deployment story: O(D^2) state, independent of max_len
        hd = cfg.resolved_head_dim
        return init_state(batch, cfg.num_kv_heads, hd, hd)

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        o, cache = la_attention_prefill(q, k, v, cfg.la, state=cache)
        return self.out(p, o, compute_dtype), cache

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        q, k, v = self.project_qkv(p, cfg, x, position, compute_dtype)
        cache, o = la_attention_decode(
            cache, q[:, :, 0], k[:, :, 0], v[:, :, 0], cfg.la)
        return self.out(p, o[:, :, None], compute_dtype), cache

    # -- cross-attention serving state (whisper decode) ----------------

    def cross_precompute(self, p, cfg, ctx, compute_dtype=None) -> CrossState:
        """Precompute the LA cross-attention state from encoder output."""
        hd = cfg.resolved_head_dim
        k = split_heads(dense(p["wk"], ctx, compute_dtype),
                        cfg.num_kv_heads, hd)
        v = split_heads(dense(p["wv"], ctx, compute_dtype),
                        cfg.num_kv_heads, hd)
        if cfg.la.normalize_qk:
            k = l2_normalize(k)
        vaug = jnp.concatenate(
            [v.astype(F32), jnp.ones(v.shape[:-1] + (1,), F32)], -1)
        s = jnp.einsum("bhjd,bhje->bhde", k.astype(F32), vaug,
                       preferred_element_type=F32)
        return CrossState(s=s, p=vaug.sum(axis=-2))

    def cross_decode(self, p, cfg, x, state: CrossState, compute_dtype=None):
        """One-token cross-attention readout against the precomputed state."""
        hd = cfg.resolved_head_dim
        b = x.shape[0]
        q = split_heads(dense(p["wq"], x, compute_dtype), cfg.num_heads, hd)
        if cfg.la.normalize_qk:
            q = l2_normalize(q)
        hkv = state.s.shape[1]
        g = cfg.num_heads // hkv
        qg = q[:, :, 0].reshape(b, hkv, g, hd).astype(F32)
        la = cfg.la
        f = (la.a * state.p[:, :, None, :]
             + la.b * jnp.einsum("bhgd,bhde->bhge", qg, state.s,
                                 preferred_element_type=F32))
        o = f[..., :hd] / f[..., hd:]
        o = o.reshape(b, cfg.num_heads, 1, hd).astype(x.dtype)
        return self.out(p, o, compute_dtype)
