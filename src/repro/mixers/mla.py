"""Multi-head Latent Attention (DeepSeek-V2) with the paper's LA scores.

The latent KV compression (kv_lora_rank=512) and rope/nope head split are
kept from DeepSeek-V2; after per-head decompression the paper's normalized
linear attention replaces softmax.  Adaptation note (DESIGN.md §Arch-
applicability): with the linear scores the decode cache is the LA
recurrent state — the compressed-KV cache that motivates MLA is subsumed,
but the parameterization (low-rank Q/KV projections) is preserved.
(A softmax-scored MLA would be a new one-file backend; see ROADMAP.)

q : d -> q_lora -> H x (nope + rope)         (q_lora_rank = 1536)
kv: d -> kv_lora (512) + shared k_rope (64)
k : per head [k_nope(from kv_lora), k_rope(shared, rotated)]
v : per head v_head_dim (128) from kv_lora
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear_attention import la_attention, la_attention_decode, \
    la_attention_prefill
from repro.distributed.act_sharding import BATCH, MODEL, constrain
from repro.mixers.base import AttentionBackend, register_backend
from repro.mixers.cache import init_state
from repro.mixers.qkv import merge_heads
from repro.models.common import dense, dense_init, norm_apply, norm_init
from repro.models.rope import apply_rope

F32 = jnp.float32


@register_backend("mla")
class MLABackend(AttentionBackend):
    def init(self, key, cfg, dtype=F32):
        m = cfg.mla
        h = cfg.num_heads
        ks = jax.random.split(key, 7)
        qk_head = m.nope_head_dim + m.rope_head_dim
        return {
            "wq_down": dense_init(ks[0], cfg.d_model, m.q_lora_rank,
                                  dtype=dtype),
            "q_norm": norm_init(m.q_lora_rank, dtype=dtype),
            "wq_up": dense_init(ks[1], m.q_lora_rank, h * qk_head,
                                dtype=dtype),
            "wkv_down": dense_init(ks[2], cfg.d_model,
                                   m.kv_lora_rank + m.rope_head_dim,
                                   dtype=dtype),
            "kv_norm": norm_init(m.kv_lora_rank, dtype=dtype),
            "wk_up": dense_init(ks[3], m.kv_lora_rank, h * m.nope_head_dim,
                                dtype=dtype),
            "wv_up": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim,
                                dtype=dtype),
            "wo": dense_init(ks[5], h * m.v_head_dim, cfg.d_model,
                             dtype=dtype),
        }

    def _qkv(self, p, cfg, x, positions, compute_dtype):
        """Returns q, k: (B, H, N, nope+rope); v: (B, H, N, v_head)."""
        m = cfg.mla
        h = cfg.num_heads
        b, n, _ = x.shape

        ql = dense(p["wq_down"], x, compute_dtype)
        ql = norm_apply(p["q_norm"], ql, cfg.norm)
        q = dense(p["wq_up"], ql, compute_dtype).reshape(
            b, n, h, m.nope_head_dim + m.rope_head_dim).transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]

        kv = dense(p["wkv_down"], x, compute_dtype)
        kv_l, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
        kv_l = norm_apply(p["kv_norm"], kv_l, cfg.norm)
        k_nope = dense(p["wk_up"], kv_l, compute_dtype).reshape(
            b, n, h, m.nope_head_dim).transpose(0, 2, 1, 3)
        v = dense(p["wv_up"], kv_l, compute_dtype).reshape(
            b, n, h, m.v_head_dim).transpose(0, 2, 1, 3)

        k_rope = k_rope[:, None]  # (B, 1, N, rope) — shared across heads
        q_rope = apply_rope(q_rope, positions, "standard",
                            theta=cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, "standard",
                            theta=cfg.rope_theta)
        k_rope = jnp.broadcast_to(k_rope, (b, h, n, m.rope_head_dim))

        q = constrain(jnp.concatenate([q_nope, q_rope], -1),
                      BATCH, MODEL, None, None)
        k = constrain(jnp.concatenate([k_nope, k_rope], -1),
                      BATCH, MODEL, None, None)
        v = constrain(v, BATCH, MODEL, None, None)
        return q, k, v

    def apply(self, p, cfg, x, positions, compute_dtype=None):
        q, k, v = self._qkv(p, cfg, x, positions, compute_dtype)
        o = la_attention(q, k, v, cfg.la, causal=True)
        return dense(p["wo"], merge_heads(o), compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        m = cfg.mla
        # linear scores: recurrent state over the decompressed per-head dims
        return init_state(batch, cfg.num_heads,
                          m.nope_head_dim + m.rope_head_dim, m.v_head_dim)

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        q, k, v = self._qkv(p, cfg, x, positions, compute_dtype)
        o, cache = la_attention_prefill(q, k, v, cfg.la, state=cache)
        return dense(p["wo"], merge_heads(o), compute_dtype), cache

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        q, k, v = self._qkv(p, cfg, x, position, compute_dtype)
        cache, o = la_attention_decode(
            cache, q[:, :, 0], k[:, :, 0], v[:, :, 0], cfg.la)
        return dense(p["wo"], merge_heads(o[:, :, None]),
                     compute_dtype), cache
