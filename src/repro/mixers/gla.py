"""Decay-gated linear attention backend (GLA-style; ROADMAP top item).

The paper's normalized f(x) = a + b x linear attention (the `linear`
backend) with a LEARNED per-KV-head, per-token decay gate multiplying
the running KV state — the expressivity upgrade of Yang et al., "Gated
Linear Attention Transformers with Hardware-Efficient Training", built
on the paper's chunked-recurrence + analytic-backward discipline
(core/gla.py, kernels/gla.py, registered as the "gla" KernelImpl
family).

The gate is a single dense head per layer: log_decay =
log_sigmoid(x @ wg + DECAY_BIAS), one scalar per token per KV head
(the decayed state is per KV head and shared across the query group, so
the gate never materializes an H-fold copy).  DECAY_BIAS shifts the
init toward gamma ~ 1, where the backend starts as EXACTLY the linear
family (log_decay == 0 is the parity anchor in tests/test_kernels_gla)
and learns to forget.

Decode keeps the paper's O(D^2) recurrent state, decay-gated
(GLAState).  With cfg.paging set, the state moves into a shared page
arena (mixers.cache.PagedGLAState): the first NON-KV state layout
through serve/paging.py's PagePool — one state page per slot, admitted
by PagedAdmission on actual state bytes (docs/paged_kv.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.numerics import l2_normalize
from repro.kernels import ops as _ops
from repro.mixers.base import register_backend
from repro.mixers.cache import GLAState, PagedGLAState, init_gla_state
from repro.mixers.qkv import GQAProjectionBackend
from repro.models.common import dense, dense_init

F32 = jnp.float32

# log_sigmoid(6) ~ -0.0025: init decay gamma ~ 0.9975 per token, so a
# fresh layer behaves like the undecayed linear family and learns to
# forget rather than having to learn to remember
DECAY_BIAS = 6.0


@register_backend("gla")
class GLAAttentionBackend(GQAProjectionBackend):
    # decay gating is a causal notion: no encoder / cross paths
    supports_noncausal = False
    # decode can run gate + state update + normalizer divide in one
    # fused kernel (kernels/decode_fused.py; docs/fused_decode.md)
    supports_fused_decode = True

    def init(self, key, cfg, dtype=F32):
        k1, k2 = jax.random.split(key)
        p = super().init(k1, cfg, dtype)
        p["wg"] = dense_init(k2, cfg.d_model, cfg.num_kv_heads,
                             bias=True, dtype=dtype)
        return p

    def _log_decay(self, p, cfg, x, compute_dtype):
        """x: (B, N, C) -> per-KV-head log decay (B, Hkv, N) <= 0."""
        logits = dense(p["wg"], x, compute_dtype)          # (B, N, Hkv)
        ld = jax.nn.log_sigmoid(logits.astype(F32) + DECAY_BIAS)
        return ld.transpose(0, 2, 1)

    def _qkv_ld(self, p, cfg, x, positions, compute_dtype):
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        if cfg.la.normalize_qk:
            # paper Eq. 22 — with a, b > 0 this keeps the decayed
            # normalizer strictly positive, like the linear family
            q, k = l2_normalize(q), l2_normalize(k)
        return q, k, v, self._log_decay(p, cfg, x, compute_dtype)

    def apply(self, p, cfg, x, positions, compute_dtype=None):
        q, k, v, ld = self._qkv_ld(p, cfg, x, positions, compute_dtype)
        la = cfg.la
        o = _ops.gla_causal(q, k, v, ld, la.a, la.b, la.chunk, la.backend)
        return self.out(p, o, compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        hd = cfg.resolved_head_dim
        if cfg.paging is not None:
            pg = cfg.paging
            # one state page per slot; unassigned rows -> the engine's
            # reserved sink page (last arena page), like the paged-KV
            # layout.  page_size is a KV-row notion and is ignored: a
            # page IS one (Hkv, Dk, Dv+1) state block.
            return PagedGLAState(
                s_pages=jnp.zeros((pg.num_pages, cfg.num_kv_heads, hd,
                                   hd + 1), F32),
                p_pages=jnp.zeros((pg.num_pages, cfg.num_kv_heads,
                                   hd + 1), F32),
                page_table=jnp.full((batch, 1), pg.num_pages - 1,
                                    jnp.int32),
            )
        return init_gla_state(batch, cfg.num_kv_heads, hd, hd)

    @staticmethod
    def _gather_state(cache: PagedGLAState) -> GLAState:
        page = cache.page_table[:, 0]
        return GLAState(s=cache.s_pages[page], p=cache.p_pages[page])

    @staticmethod
    def _scatter_state(cache: PagedGLAState, st: GLAState) -> PagedGLAState:
        # live slots own distinct pages (engine invariant); retired
        # slots share the sink page, where last-write-wins is fine
        page = cache.page_table[:, 0]
        return cache._replace(
            s_pages=cache.s_pages.at[page].set(st.s.astype(F32)),
            p_pages=cache.p_pages.at[page].set(st.p.astype(F32)))

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        q, k, v, ld = self._qkv_ld(p, cfg, x, positions, compute_dtype)
        la = cfg.la
        paged = isinstance(cache, PagedGLAState)
        st = self._gather_state(cache) if paged else cache
        o, st = _ops.gla_prefill(q, k, v, ld, la.a, la.b, la.chunk,
                                 state=st)
        cache = self._scatter_state(cache, st) if paged else st
        return self.out(p, o, compute_dtype), cache

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        q, k, v, ld = self._qkv_ld(p, cfg, x, position, compute_dtype)
        la = cfg.la
        paged = isinstance(cache, PagedGLAState)
        st = self._gather_state(cache) if paged else cache
        if la.fused_decode and self.supports_fused_decode:
            st, o = _ops.gla_decode_step_fused(
                st, q[:, :, 0], k[:, :, 0], v[:, :, 0], ld[:, :, 0],
                la.a, la.b, backend=la.backend)
        else:
            st, o = _ops.gla_decode_step(st, q[:, :, 0], k[:, :, 0],
                                         v[:, :, 0], ld[:, :, 0],
                                         la.a, la.b)
        cache = self._scatter_state(cache, st) if paged else st
        return self.out(p, o[:, :, None], compute_dtype), cache
