"""Mamba-2 (SSD) token-mixer backend.

The paper (Appendix B, Table 3) identifies Mamba-2's recurrence
S_t = gamma_t S_{t-1} + k_t v_t^T as decay-gated linear attention; this
backend reuses the chunked-scan machinery of core/ssd.py with
q = C, k = B (shared across heads, like MQA) and v = x heads.

Layer structure (Mamba-2 paper / mamba_ssm reference):
  in_proj: d -> [z(d_in), x(d_in), B(state), C(state), dt(H)]
  causal depthwise conv(width 4) + silu over [x, B, C]
  dt = softplus(dt + dt_bias); log_decay = -dt * exp(A_log)
  o = SSD(C, B, x * dt, log_decay) + D ⊙ x
  y = RMSNorm(o ⊙ silu(z)); out_proj: d_in -> d

`fuses_ffn = True`: the mamba block IS both token and channel mixer, so
blocks.py adds no separate FFN / second norm around it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ssd import init_ssd_state, ssd_decode_step, ssd_fwd_chunked
from repro.kernels.ops import ssd_causal
from repro.distributed.act_sharding import BATCH, MODEL, constrain
from repro.mixers.base import AttentionBackend, register_backend
from repro.mixers.cache import MambaCache
from repro.models.common import dense, dense_init, norm_apply, norm_init

F32 = jnp.float32


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return d_in, nheads, conv_ch


def _causal_conv(x, w, b, left=None):
    """Depthwise causal conv. x: (B, N, C); w: (W, C).  O(W) per token.

    left: optional (B, W-1, C) context from a previous window (chunked
    prefill); defaults to zeros (sequence start)."""
    width = w.shape[0]
    if left is None:
        pads = jnp.pad(x, [(0, 0), (width - 1, 0), (0, 0)])
    else:
        pads = jnp.concatenate([left, x], axis=1)
    out = sum(pads[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    return out + b.astype(x.dtype)


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_in, nheads, _ = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * s.state_dim]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _ssd_inputs(cfg, xbc, dt, dt_bias, a_log):
    """conv'd xbc + raw dt -> (q, k, v, log_decay) for the SSD scan.

    q/k (Mamba-2's C/B) are shared across heads: returned as (B, 1, N,
    state) and the grouped SSD computes Q K^T once (core/ssd.py) —
    materializing per-head copies would cost an H-fold blowup.
    """
    s = cfg.ssm
    d_in, nheads, _ = _dims(cfg)
    b, n, _ = xbc.shape
    xs = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + s.state_dim]
    cmat = xbc[..., d_in + s.state_dim:]
    dt_f = jax.nn.softplus(dt.astype(F32) + dt_bias)          # (B, N, H)
    log_decay = (-dt_f * jnp.exp(a_log)).transpose(0, 2, 1)   # (B, H, N)
    log_decay = constrain(log_decay, BATCH, MODEL, None)
    v = xs.reshape(b, n, nheads, s.head_dim).transpose(0, 2, 1, 3)
    v = constrain(v, BATCH, MODEL, None, None)
    v_eff = v * dt_f.transpose(0, 2, 1)[..., None].astype(v.dtype)
    q = cmat[:, None]                                         # (B,1,N,state)
    k = bmat[:, None]
    return q, k, v, v_eff, log_decay


@register_backend("mamba2")
class Mamba2Backend(AttentionBackend):
    fuses_ffn = True  # the mamba block carries no separate FFN

    def init(self, key, cfg, dtype=F32):
        s = cfg.ssm
        d_in, nheads, conv_ch = _dims(cfg)
        ks = jax.random.split(key, 4)
        return {
            "in_proj": dense_init(ks[0], cfg.d_model,
                                  2 * d_in + 2 * s.state_dim + nheads,
                                  dtype=dtype),
            "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_ch),
                                         F32)
                       * (1.0 / s.conv_width) ** 0.5).astype(dtype),
            "conv_b": jnp.zeros((conv_ch,), dtype),
            "a_log": jnp.zeros((nheads,), F32),  # exp(a_log)=1 decay rate
            "dt_bias": jnp.zeros((nheads,), F32),
            "d_skip": jnp.ones((nheads,), F32),
            "norm": norm_init(d_in, dtype=dtype),
            "out_proj": dense_init(ks[2], d_in, cfg.d_model, dtype=dtype),
        }

    def apply(self, p, cfg, x, positions=None, compute_dtype=None):
        zxbcdt = constrain(dense(p["in_proj"], x, compute_dtype,
                                 gather_weight=True),
                           BATCH, None, MODEL)
        z, xbc, dt = _split_proj(cfg, zxbcdt)
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        xbc = constrain(xbc, BATCH, None, MODEL)
        q, k, v, v_eff, log_decay = _ssd_inputs(cfg, xbc, dt, p["dt_bias"],
                                                p["a_log"])
        if cfg.ssm.analytic_bwd:
            o = ssd_causal(q, k, v_eff, log_decay, cfg.la.chunk,
                           cfg.la.backend)
        else:
            o, _ = ssd_fwd_chunked(q, k, v_eff, log_decay,
                                   chunk=cfg.la.chunk)
        o = o + p["d_skip"][None, :, None, None].astype(o.dtype) * v
        b_, h_, n_, hd = o.shape
        o = o.transpose(0, 2, 1, 3).reshape(b_, n_, h_ * hd)
        y = norm_apply(p["norm"], o * jax.nn.silu(z).astype(o.dtype),
                       cfg.norm)
        return dense(p["out_proj"], y, compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        s = cfg.ssm
        d_in, nheads, conv_ch = _dims(cfg)
        return MambaCache(
            ssd=init_ssd_state(batch, nheads, s.state_dim, s.head_dim),
            conv=jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
        )

    def prefill(self, p, cfg, x, positions, cache: MambaCache,
                compute_dtype=None):
        zxbcdt = dense(p["in_proj"], x, compute_dtype)
        z, xbc, dt = _split_proj(cfg, zxbcdt)
        # continuation-correct conv: the left context is the previous
        # window's tail from the cache (zeros on a fresh cache); the new
        # tail spans [left, window] so windows shorter than the conv
        # width still carry the right context
        left = cache.conv.astype(xbc.dtype)
        tail = jnp.concatenate([left, xbc], axis=1)[
            :, -(cfg.ssm.conv_width - 1):].astype(cache.conv.dtype)
        xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"],
                                       left=left))
        q, k, v, v_eff, log_decay = _ssd_inputs(cfg, xbc, dt, p["dt_bias"],
                                                p["a_log"])
        o, ssd_st = ssd_fwd_chunked(q, k, v_eff, log_decay,
                                    chunk=cfg.la.chunk, state=cache.ssd)
        o = o + p["d_skip"][None, :, None, None].astype(o.dtype) * v
        b_, h_, n_, hd = o.shape
        o = o.transpose(0, 2, 1, 3).reshape(b_, n_, h_ * hd)
        y = norm_apply(p["norm"], o * jax.nn.silu(z).astype(o.dtype),
                       cfg.norm)
        return dense(p["out_proj"], y, compute_dtype), MambaCache(ssd_st,
                                                                  tail)

    def decode(self, p, cfg, x, position, cache: MambaCache,
               compute_dtype=None):
        """x: (B, 1, C) — one token; O(D_state * hd) per head per token."""
        zxbcdt = dense(p["in_proj"], x, compute_dtype)
        z, xbc, dt = _split_proj(cfg, zxbcdt)
        window = jnp.concatenate(
            [cache.conv.astype(xbc.dtype), xbc], axis=1)  # (B, W, C)
        new_conv = window[:, 1:].astype(cache.conv.dtype)
        conv_out = jnp.einsum("bwc,wc->bc", window.astype(F32),
                              p["conv_w"].astype(F32)) \
            + p["conv_b"].astype(F32)
        xbc1 = jax.nn.silu(conv_out)[:, None].astype(xbc.dtype)
        q, k, v, v_eff, log_decay = _ssd_inputs(cfg, xbc1, dt, p["dt_bias"],
                                                p["a_log"])
        ssd_st, o = ssd_decode_step(cache.ssd, q[:, :, 0], k[:, :, 0],
                                    v_eff[:, :, 0], log_decay[:, :, 0])
        o = o + p["d_skip"][None, :, None].astype(o.dtype) * v[:, :, 0]
        b_ = o.shape[0]
        o = o.reshape(b_, 1, -1)
        y = norm_apply(p["norm"], o * jax.nn.silu(z).astype(o.dtype),
                       cfg.norm)
        return dense(p["out_proj"], y, compute_dtype), MambaCache(ssd_st,
                                                                  new_conv)
