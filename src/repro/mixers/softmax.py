"""Softmax-attention backend — the Regular-Attention baseline.

Scores go through the "softmax" KernelImpl family in kernels.ops:
cfg.la.backend picks chunked online-softmax (xla) or the Pallas flash
kernel (pallas / pallas_interpret).  Both TRAIN: the xla scan
differentiates by autodiff, the flash kernel through the custom-vjp
registered in kernels.ops (flash v2's recomputation-based backward), so
"auto" resolving to pallas on TPU gives a trainable baseline.  The
flash kernel is also GQA-native and understands per-slot q_offset, so
continuation prefill below runs through Pallas too — no XLA fallback.

Decode keeps an O(S) KVCache per layer and is PER-SLOT position correct:
each continuously-batched slot scatters its new k/v at its own absolute
position and masks its own context length, so slots at different depths
decode exactly (this is what the O(D^2) linear backend gets for free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as _ops
from repro.mixers.base import register_backend
from repro.mixers.cache import KVCache
from repro.mixers.qkv import GQAProjectionBackend

F32 = jnp.float32


def _pos2d(positions):
    """(B, N) positions; mrope (3, B, N) uses the temporal stream."""
    return positions if positions.ndim == 2 else positions[0]


def _scatter_window(big, new, start):
    """Write `new` (B, Hkv, n, hd) into `big` at per-slot offsets (B,)."""
    def one(b1, n1, s1):
        return jax.lax.dynamic_update_slice(b1, n1, (0, s1, 0))
    return jax.vmap(one)(big, new.astype(big.dtype), start)


@register_backend("softmax")
class SoftmaxAttentionBackend(GQAProjectionBackend):
    def apply(self, p, cfg, x, positions, compute_dtype=None):
        # every impl is trainable (flash v2 registered a custom vjp), so
        # cfg.la.backend flows straight through — "auto" = pallas on TPU
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        o = _ops.softmax_attention(q, k, v, causal=True, chunk=cfg.la.chunk,
                                   backend=cfg.la.backend)
        return self.out(p, o, compute_dtype)

    def apply_noncausal(self, p, cfg, x, ctx, positions=None,
                        compute_dtype=None):
        q, k, v = self.project_noncausal(p, cfg, x, ctx, positions,
                                         compute_dtype)
        o = _ops.softmax_attention(q, k, v, causal=False,
                                   chunk=cfg.la.chunk,
                                   backend=cfg.la.backend)
        return self.out(p, o, compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        hd = cfg.resolved_head_dim
        return KVCache(
            k=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
            v=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        )

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        """CONTINUATION prefill: the window's k/v are scattered at each
        slot's absolute offset, then the window queries attend to the
        whole cached prefix plus themselves (per-slot `q_offset` causal
        mask) — chunked prefill is exact for the baseline too, matching
        what the recurrent backends get from their carried state.  On
        the pallas impls the offsets ride the flash kernel's scalar
        prefetch (KV walk bounded at the deepest slot's frontier)."""
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        start = _pos2d(positions)[:, 0]
        cache = KVCache(k=_scatter_window(cache.k, k, start),
                        v=_scatter_window(cache.v, v, start))
        o = _ops.softmax_attention(q, cache.k, cache.v, causal=True,
                                   chunk=cfg.la.chunk,
                                   backend=cfg.la.backend, q_offset=start)
        return self.out(p, o, compute_dtype), cache

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        """x: (B, 1, C); position: (B, 1) PER-SLOT absolute positions."""
        q, k, v = self.project_qkv(p, cfg, x, position, compute_dtype)
        pos = _pos2d(position)[:, 0]                       # (B,)
        cache = KVCache(k=_scatter_window(cache.k, k, pos),
                        v=_scatter_window(cache.v, v, pos))
        b, hkv, s, hd = cache.k.shape
        # per-slot context length: slot i attends to its first pos_i+1 keys
        mask_j = (jax.lax.broadcasted_iota(jnp.int32, (b, s), 1)
                  <= pos[:, None])                          # (B, S)
        g = cfg.num_heads // hkv
        qg = q.reshape(b, hkv, g, 1, hd).astype(F32)
        s_ = jnp.einsum("bhgid,bhjd->bhgij", qg, cache.k.astype(F32),
                        preferred_element_type=F32) / hd ** 0.5
        s_ = jnp.where(mask_j[:, None, None, None, :], s_, -1e30)
        pmat = jax.nn.softmax(s_, axis=-1)
        o = jnp.einsum("bhgij,bhjd->bhgid", pmat, cache.v.astype(F32),
                       preferred_element_type=F32)
        o = o.reshape(b, cfg.num_heads, 1, hd).astype(x.dtype)
        return self.out(p, o, compute_dtype), cache
