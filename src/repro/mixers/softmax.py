"""Softmax-attention backend — the Regular-Attention baseline.

Scores go through the "softmax" KernelImpl family in kernels.ops:
cfg.la.backend picks chunked online-softmax (xla) or the Pallas flash
kernel (pallas / pallas_interpret).  Both TRAIN: the xla scan
differentiates by autodiff, the flash kernel through the custom-vjp
registered in kernels.ops (flash v2's recomputation-based backward), so
"auto" resolving to pallas on TPU gives a trainable baseline.  The
flash kernel is also GQA-native and understands per-slot q_offset, so
continuation prefill below runs through Pallas too — no XLA fallback.

Decode is PER-SLOT position correct: each continuously-batched slot
scatters its new k/v at its own absolute position and masks its own
context length, so slots at different depths decode exactly (this is
what the O(D^2) linear backend gets for free).  Both decode layouts go
through the kernels.ops registry:

  contiguous  O(S) KVCache per layer, "softmax_decode" family (xla)
  paged       cfg.paging set: a PagedKVCache of fixed-size KV blocks
              shared across slots, addressed by per-slot page tables —
              the "paged" family, whose pallas impls gather pages via
              scalar prefetch (kernels/paged_attention.py), so decode
              runs through a kernel, not an einsum (docs/paged_kv.md)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as _ops
from repro.mixers.base import register_backend
from repro.mixers.cache import KVCache, PagedKVCache
from repro.mixers.qkv import GQAProjectionBackend


def _pos2d(positions):
    """(B, N) positions; mrope (3, B, N) uses the temporal stream."""
    return positions if positions.ndim == 2 else positions[0]


def _scatter_window(big, new, start):
    """Write `new` (B, Hkv, n, hd) into `big` at per-slot offsets (B,)."""
    def one(b1, n1, s1):
        return jax.lax.dynamic_update_slice(b1, n1, (0, s1, 0))
    return jax.vmap(one)(big, new.astype(big.dtype), start)


def _write_pages(pages, new, page_table, positions):
    """Write `new` (B, Hkv, n, hd) into the shared (P, Hkv, ps, hd)
    arena at ABSOLUTE positions (B, n), routed through the page table.
    Slots own their pages exclusively (the pool copies any shared
    frontier page on fork), so the scattered (page, offset) pairs never
    collide across the batch."""
    b, hkv, n, hd = new.shape
    ps = pages.shape[2]
    # clamp the page-table lookup: a RETIRED slot's position counter
    # keeps advancing past its table (it decodes on as batch padding),
    # and its whole row points at the engine's sink page anyway
    idx = jnp.minimum(positions // ps, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, idx, axis=1)
    off = positions % ps
    vals = new.transpose(0, 2, 1, 3).reshape(b * n, hkv, hd)
    return pages.at[page.reshape(-1), :, off.reshape(-1)].set(
        vals.astype(pages.dtype))


@register_backend("softmax")
class SoftmaxAttentionBackend(GQAProjectionBackend):
    # decode can fold the finalize divide + GQA head-fold into the
    # kernel epilogue (kernels/decode_fused.py; docs/fused_decode.md)
    supports_fused_decode = True

    def apply(self, p, cfg, x, positions, compute_dtype=None):
        # every impl is trainable (flash v2 registered a custom vjp), so
        # cfg.la.backend flows straight through — "auto" = pallas on TPU
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        o = _ops.softmax_attention(q, k, v, causal=True, chunk=cfg.la.chunk,
                                   backend=cfg.la.backend)
        return self.out(p, o, compute_dtype)

    def apply_noncausal(self, p, cfg, x, ctx, positions=None,
                        compute_dtype=None):
        q, k, v = self.project_noncausal(p, cfg, x, ctx, positions,
                                         compute_dtype)
        o = _ops.softmax_attention(q, k, v, causal=False,
                                   chunk=cfg.la.chunk,
                                   backend=cfg.la.backend)
        return self.out(p, o, compute_dtype)

    def init_cache(self, cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
        hd = cfg.resolved_head_dim
        if cfg.paging is not None:
            pg = cfg.paging
            pages_per_seq = -(-max_len // pg.page_size)
            arena = (pg.num_pages, cfg.num_kv_heads, pg.page_size, hd)
            # unallocated table entries point at the LAST arena page —
            # the engine reserves it as a write sink for retired slots,
            # so a stale slot's decode writes never touch a live page
            return PagedKVCache(
                k_pages=jnp.zeros(arena, dtype),
                v_pages=jnp.zeros(arena, dtype),
                page_table=jnp.full((batch, pages_per_seq),
                                    pg.num_pages - 1, jnp.int32),
            )
        return KVCache(
            k=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
            v=jnp.zeros((batch, cfg.num_kv_heads, max_len, hd), dtype),
        )

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        """CONTINUATION prefill: the window's k/v are scattered at each
        slot's absolute offset, then the window queries attend to the
        whole cached prefix plus themselves (per-slot `q_offset` causal
        mask) — chunked prefill is exact for the baseline too, matching
        what the recurrent backends get from their carried state.  On
        the pallas impls the offsets ride the flash kernel's scalar
        prefetch (KV walk bounded at the deepest slot's frontier).

        With cfg.paging the window writes DIRECTLY into the slot's
        allocated arena pages, then attends to a page-table gather of
        its context (keys past the causal frontier — including whatever
        the sink page holds — are masked by the q_offset causal mask)."""
        q, k, v = self.project_qkv(p, cfg, x, positions, compute_dtype)
        pos2d = _pos2d(positions)
        start = pos2d[:, 0]
        if isinstance(cache, PagedKVCache):
            from repro.kernels.paged_attention import gather_pages
            cache = cache._replace(
                k_pages=_write_pages(cache.k_pages, k, cache.page_table,
                                     pos2d),
                v_pages=_write_pages(cache.v_pages, v, cache.page_table,
                                     pos2d))
            kc = gather_pages(cache.k_pages, cache.page_table)
            vc = gather_pages(cache.v_pages, cache.page_table)
        else:
            cache = KVCache(k=_scatter_window(cache.k, k, start),
                            v=_scatter_window(cache.v, v, start))
            kc, vc = cache.k, cache.v
        o = _ops.softmax_attention(q, kc, vc, causal=True,
                                   chunk=cfg.la.chunk,
                                   backend=cfg.la.backend, q_offset=start)
        return self.out(p, o, compute_dtype), cache

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        """x: (B, 1, C); position: (B, 1) PER-SLOT absolute positions.

        Contiguous: scatter at the slot's position, then the
        "softmax_decode" registry impl masks each slot's own context
        length (slot i attends to its first pos_i + 1 keys).  Paged:
        write the token into the slot's current page and run the
        "paged" family kernel — K/V pages are gathered through the
        scalar-prefetched page table on the pallas impls."""
        q, k, v = self.project_qkv(p, cfg, x, position, compute_dtype)
        pos2d = _pos2d(position)
        pos = pos2d[:, 0]                                  # (B,)
        if isinstance(cache, PagedKVCache):
            cache = cache._replace(
                k_pages=_write_pages(cache.k_pages, k, cache.page_table,
                                     pos2d),
                v_pages=_write_pages(cache.v_pages, v, cache.page_table,
                                     pos2d))
            fused = cfg.la.fused_decode and self.supports_fused_decode
            paged_decode = (_ops.paged_attention_fused if fused
                            else _ops.paged_attention)
            o = paged_decode(q, cache.k_pages, cache.v_pages,
                             cache.page_table, pos + 1,
                             backend=cfg.la.backend)
        else:
            cache = KVCache(k=_scatter_window(cache.k, k, pos),
                            v=_scatter_window(cache.v, v, pos))
            fused = cfg.la.fused_decode and self.supports_fused_decode
            contig_decode = (_ops.softmax_decode_fused if fused
                             else _ops.softmax_decode)
            o = contig_decode(q, cache.k, cache.v, pos + 1,
                              backend=cfg.la.backend)
        return self.out(p, o.astype(x.dtype), compute_dtype), cache
