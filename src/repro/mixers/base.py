"""AttentionBackend protocol + registry — the single mixer dispatch point.

The paper's pitch is that its linear attention is a drop-in replacement
for softmax with identical end-to-end expressivity.  "Drop-in" only pays
off if swapping mechanisms is a config change, so every token mixer
(linear, softmax, MLA, Mamba-2, and whatever comes next) implements ONE
interface and registers itself by name; models, serving, launchers and
benchmarks dispatch through `get_backend(cfg)` and never branch on
backend strings inline.

Backend resolution from a ModelConfig:
  cfg.mixer == "attention"  -> cfg.attention_backend  ("linear"|"softmax")
  otherwise                 -> cfg.mixer              ("mla"|"mamba2")

Resolution also validates cfg.la (the single kernel-hyperparameter
schema, configs.base.LACfg): the kernel impl name must be registered in
kernels.ops and the chunk size positive — errors name the valid options.

Adding a backend is one file: subclass AttentionBackend, decorate with
@register_backend("name"), import the module from mixers/__init__.py.
"""
from __future__ import annotations

from repro.kernels import ops as _ops

_BACKENDS: dict[str, "AttentionBackend"] = {}


class AttentionBackend:
    """One token-mixing mechanism across train / prefill / decode.

    Implementations are stateless singletons: params and caches are
    explicit pytrees, so jit/scan/shard_map see plain functions.

    Shapes (C = d_model): x: (B, N, C); positions: (B, N) int32 absolute
    positions (mrope: (3, B, N)); decode takes x: (B, 1, C) and
    position: (B, 1) — PER-SLOT positions, slots of a continuously
    batched engine sit at different depths.
    """

    name: str = "?"
    # mamba2-style blocks fuse channel mixing into the mixer: the block
    # adds no separate FFN / second norm around it
    fuses_ffn: bool = False
    # capability flags, checked at registry-resolution time so a config
    # that needs them fails fast instead of deep inside a jitted step
    supports_noncausal: bool = False   # apply_noncausal (encoder / cross)
    supports_cross_decode: bool = False  # cross_precompute / cross_decode
    # decode() can route through the fused single-kernel decode-step
    # families of kernels/decode_fused.py when cfg.la.fused_decode is
    # set (the default); backends without a fused path ignore the flag
    supports_fused_decode: bool = False

    # -- required ------------------------------------------------------
    def init(self, key, cfg, dtype):
        """-> params pytree for one layer's mixer."""
        raise NotImplementedError

    def apply(self, p, cfg, x, positions, compute_dtype=None):
        """Causal self-attention over the full sequence (training)."""
        raise NotImplementedError

    def init_cache(self, cfg, batch: int, max_len: int, dtype):
        """-> per-layer decode cache (shape may be O(1) or O(max_len))."""
        raise NotImplementedError

    def prefill(self, p, cfg, x, positions, cache, compute_dtype=None):
        """Run a prompt window against `cache` -> (y, cache)."""
        raise NotImplementedError

    def decode(self, p, cfg, x, position, cache, compute_dtype=None):
        """One token per slot -> (y, cache).  x: (B, 1, C)."""
        raise NotImplementedError

    # -- optional capabilities ----------------------------------------
    def apply_noncausal(self, p, cfg, x, ctx, positions=None,
                        compute_dtype=None):
        """Bidirectional attention: self (ctx=x) or cross (ctx=enc)."""
        raise NotImplementedError(
            f"backend {self.name!r} has no non-causal (encoder/cross) path")

    def cross_precompute(self, p, cfg, ctx, compute_dtype=None):
        """Precompute a decode-time cross-attention state from `ctx`."""
        raise NotImplementedError(
            f"backend {self.name!r} has no cross-attention decode path")

    def cross_decode(self, p, cfg, x, state, compute_dtype=None):
        """One-token cross-attention readout against that state."""
        raise NotImplementedError(
            f"backend {self.name!r} has no cross-attention decode path")


def register_backend(name: str):
    """Class decorator: instantiate + register under `name`."""
    def deco(cls):
        cls.name = name
        _BACKENDS[name] = cls()
        return cls
    return deco


def registered_backends() -> list[str]:
    return sorted(_BACKENDS)


def resolve_backend_name(cfg) -> str:
    """ModelConfig -> registered backend name (no validation)."""
    return cfg.attention_backend if cfg.mixer == "attention" else cfg.mixer


def get_backend(cfg_or_name) -> AttentionBackend:
    """Resolve a ModelConfig (or a bare name) to its backend.

    Raises with the registered names on an unknown backend, and
    validates cfg.la at resolution time (single-schema rule: LACfg is
    the only kernel-hyperparameter schema; its impl name must exist).
    """
    if isinstance(cfg_or_name, str):
        name, cfg = cfg_or_name, None
    else:
        name, cfg = resolve_backend_name(cfg_or_name), cfg_or_name
    backend = _BACKENDS.get(name)
    if backend is None:
        raise KeyError(
            f"unknown attention backend {name!r}; registered backends: "
            f"{registered_backends()} (cfg.mixer selects mla/mamba2, "
            f"cfg.attention_backend selects linear/gla/softmax)")
    if cfg is not None:
        la = cfg.la
        if la.chunk <= 0:
            raise ValueError(f"cfg.la.chunk must be positive, got {la.chunk}")
        if la.backend != "auto":
            # every mixer keys its kernel impl off cfg.la.backend; the
            # linear/softmax/ssd/gla families share the impl namespace
            family = {"softmax": "softmax", "mamba2": "ssd",
                      "gla": "gla"}.get(name, "linear")
            _ops.get_kernel(family, la.backend)
        if cfg.paging is not None:
            if name not in ("softmax", "gla"):
                raise ValueError(
                    f"cfg.paging is a serving feature of the softmax "
                    f"(paged-KV rows) and gla (paged recurrent state) "
                    f"backends; backend {name!r} keeps its own "
                    f"non-paged decode cache — unset paging or switch "
                    f"backends")
            if cfg.paging.page_size < 1 or cfg.paging.num_pages < 2:
                raise ValueError(
                    f"cfg.paging needs page_size >= 1 and num_pages >= 2 "
                    f"(one page is the engine's reserved write sink), got "
                    f"page_size={cfg.paging.page_size} "
                    f"num_pages={cfg.paging.num_pages}")
        if cfg.family == "encdec" and not (backend.supports_noncausal
                                           and backend.supports_cross_decode):
            capable = [n for n, b in _BACKENDS.items()
                       if b.supports_noncausal and b.supports_cross_decode]
            raise ValueError(
                f"family 'encdec' needs a backend with encoder and "
                f"cross-attention-decode paths; {name!r} has none — "
                f"capable backends: {capable}")
    return backend


# SNIPPETS.md Based-mixer exemplar asked for exactly this name
get_mixer = get_backend
