"""Token-mixer backends behind one `AttentionBackend` interface.

Public API:
  get_backend(cfg_or_name) / get_mixer  — resolve + validate a backend
  register_backend(name)                — class decorator for new backends
  registered_backends()                 — names, for error messages / docs
  cache                                 — the per-backend cache namespace

Importing this package registers the five built-in backends:
linear (the paper), gla (decay-gated LA), softmax (baseline), mla,
mamba2.  See docs/attention_backends.md for how to add one.
"""
from repro.mixers.base import AttentionBackend, get_backend, get_mixer, \
    register_backend, registered_backends, resolve_backend_name
from repro.mixers import cache  # noqa: F401  (re-exported namespace)
from repro.mixers import gla, linear, mamba2, mla, softmax  # noqa: F401  (register)

__all__ = [
    "AttentionBackend", "get_backend", "get_mixer", "register_backend",
    "registered_backends", "resolve_backend_name", "cache",
]
