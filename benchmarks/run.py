"""Benchmark harness — one entry per paper table/figure.

CPU container caveat: the paper's absolute numbers are A6000 wall-clock;
what IS hardware-independent — and what these benchmarks check — are the
paper's scaling claims (slopes) and memory ratios:

  table1   Table 1  — fwd time + memory of LA vs flash vs quadratic LA
  fig2     Fig. 2   — forward scaling in N (linear for LA, quadratic for
                      regular) and in D (quadratic for LA)
  fig3     Fig. 3   — backward scaling in N + residual memory ratio
                      (the O(ND) analytic backward vs O(ND^2) autodiff)
  fig4     Fig. 4   — data-movement proxy: HBM-traffic per token from the
                      structural HLO model (the paper measures dram reads)
  fig5     Fig. 5   — end-to-end LLM training: LA vs softmax loss curves
                      on the paper's pythia architecture (reduced scale)
  serve              — serving-engine tokens/s per backend + byte-budget
                      admission counts (O(D^2) state vs O(S) KV cache)
  serve_lat          — serving latency DISTRIBUTIONS via repro.obs: a
                      mixed long-prompt + short-chat workload traced
                      through the engine per backend family (linear /
                      gla / softmax / paged); emits
                      artifacts/BENCH_serve.json with ttft +
                      inter-token p50/p99 and mean slot occupancy per
                      cell (kind "serve_lat" — bench_check validates
                      the percentile schema instead of rooflines)
  flash              — softmax-baseline fwd+bwd, xla scan vs the flash
                      pallas kernel (flash v2 custom vjp) at N ∈ {1k,4k}
                      under GQA; emits artifacts/BENCH_flash.json.  On
                      CPU the compiled-pallas rows are skipped and a
                      small interpret-mode parity cell exercises the
                      kernel instead
  gla                — decay-gated LA fwd+bwd, xla scan vs the pallas
                       GLA kernel at N ∈ {1k,4k} under GQA; emits
                       artifacts/BENCH_gla.json (CPU: pallas rows null,
                       interpret parity cell asserted by CI)
  paged              — decode tokens/s, paged-KV kernel vs the contiguous
                       per-slot decode, at context N ∈ {1k, 8k}; emits
                       artifacts/BENCH_paged.json with an interpret-mode
                       parity cell (CI asserts on it)
  decode             — fused single-kernel decode step vs the unfused
                       composition, every family, B ∈ {8, 64}; emits
                       artifacts/BENCH_decode.json with per-family
                       interpret parity cells (CI asserts on them)
  tune               — autotune sweep per kernel family (repro.tune):
                       every legal tile candidate measured through the
                       production dispatch path; winners persist to
                       artifacts/tune_cache.json, the full candidate x
                       roofline record to artifacts/BENCH_autotune.json
  roofline           — prints the 40-cell tables from artifacts/dryrun

Every entry prints `name,metric,value` CSV rows; timing goes through
repro.tune.timer.measure (compile-excluded, device-synchronized,
median-of-k) everywhere, and the flash/gla/paged/tune JSON artifacts
carry a roofline cell (achieved-vs-roofline fraction, or null with the
denominator still present for skipped cells) per measurement.

    PYTHONPATH=src python -m benchmarks.run [entry ...]
"""
from __future__ import annotations

import sys
from repro.tune.timer import now

import jax
import jax.numpy as jnp
import numpy as np


def _t(fn, *args, reps=5):
    """Median wall-clock seconds via the repo's ONE timing methodology
    (repro.tune.timer): warmup excluded, every rep device-synchronized."""
    from repro.tune.timer import measure
    return measure(fn, *args, reps=reps, warmup=1).median_s


def _roof(family, shape, t_s=None, op="fwd"):
    """Roofline cell for one bench measurement: structural flops/bytes,
    the roofline time denominator, and achieved_frac (None when the
    cell was skipped — the denominator is still present, which is what
    bench_check / CI assert on)."""
    from repro.analysis.roofline import attention_costs, kernel_roofline
    costs = attention_costs(family, shape, op=op)
    return kernel_roofline(costs["flops"], costs["bytes"], time_s=t_s)


def _qkv(b, h, n, d, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    from repro.core.numerics import l2_normalize
    q = l2_normalize(jax.random.normal(ks[0], (b, h, n, d)))
    k = l2_normalize(jax.random.normal(ks[1], (b, h, n, d)))
    v = jax.random.normal(ks[2], (b, h, n, d))
    return q, k, v


# ---------------------------------------------------------------------------

def bench_table1():
    """Paper Table 1 at reduced scale (B=2, H=4, D=64, N=4096 on CPU):
    time + peak residual memory of one fwd pass, causal."""
    from repro.core.ssd import ssd_fwd_chunked
    from repro.kernels import ops, ref
    b, h, n, d = 2, 4, 4096, 64
    q, k, v = _qkv(b, h, n, d)
    ld = jnp.full((b, h, n), -0.01)  # GLA stand-in: decay-gated chunked LA

    la = jax.jit(lambda q, k, v: ops.la_causal(q, k, v, 1.0, 1.0, 128,
                                               "xla"))
    sm = jax.jit(lambda q, k, v: ops.softmax_attention(q, k, v,
                                                       backend="xla"))
    quad = jax.jit(lambda q, k, v: ref.la_ref(q, k, v))
    gla = jax.jit(lambda q, k, v: ssd_fwd_chunked(q, k, v, ld, 128)[0])

    t_la = _t(la, q, k, v)
    t_sm = _t(sm, q, k, v)
    t_quad = _t(quad, q, k, v)
    t_gla = _t(gla, q, k, v)
    print(f"table1,our_la_fwd_ms,{t_la*1e3:.2f}")
    print(f"table1,softmax_chunked_fwd_ms,{t_sm*1e3:.2f}")
    print(f"table1,quadratic_la_fwd_ms,{t_quad*1e3:.2f}")
    print(f"table1,gla_chunked_fwd_ms,{t_gla*1e3:.2f}")
    print(f"table1,speedup_vs_quadratic,{t_quad/t_la:.2f}")
    print(f"table1,speedup_vs_gla,{t_gla/t_la:.2f}")
    # memory: O(ND) for ours vs O(N^2) attention matrix for quadratic
    ours = 4 * b * h * n * d * 4
    quad_m = b * h * n * n * 4
    print(f"table1,our_la_fwd_bytes,{ours}")
    print(f"table1,quadratic_bytes,{quad_m}")
    print(f"table1,memory_ratio_quad_over_ours,{quad_m/ours:.1f}")


def bench_fig2():
    """Forward scaling: slope of log t vs log N (LA ~1, softmax ~2 for
    the quadratic part) and log t vs log D (LA ~<=2)."""
    from repro.kernels import ops, ref
    b, h, d = 2, 2, 64
    ns = [512, 1024, 2048, 4096]
    la_ts, sm_ts = [], []
    la = jax.jit(lambda q, k, v: ops.la_causal(q, k, v, 1.0, 1.0, 128,
                                               "xla"))
    quad = jax.jit(lambda q, k, v: ref.softmax_ref(q, k, v))
    for n in ns:
        q, k, v = _qkv(b, h, n, d)
        la_ts.append(_t(la, q, k, v, reps=3))
        sm_ts.append(_t(quad, q, k, v, reps=3))
    la_slope = np.polyfit(np.log(ns), np.log(la_ts), 1)[0]
    sm_slope = np.polyfit(np.log(ns), np.log(sm_ts), 1)[0]
    for n, t1, t2 in zip(ns, la_ts, sm_ts):
        print(f"fig2,la_fwd_ms_n{n},{t1*1e3:.2f}")
        print(f"fig2,softmax_fwd_ms_n{n},{t2*1e3:.2f}")
    print(f"fig2,la_slope_vs_N,{la_slope:.2f}")
    print(f"fig2,softmax_slope_vs_N,{sm_slope:.2f}")

    ds = [32, 64, 128]
    d_ts = []
    for d_ in ds:
        q, k, v = _qkv(b, h, 2048, d_)
        d_ts.append(_t(la, q, k, v, reps=3))
    d_slope = np.polyfit(np.log(ds), np.log(d_ts), 1)[0]
    print(f"fig2,la_slope_vs_D,{d_slope:.2f}")


def bench_fig3():
    """Backward: time scaling in N + the memory claim — residuals of the
    analytic backward (O(ND)) vs autodiff of the chunked scan (which
    stores O(N D^2 / C) chunk states)."""
    from repro.core import chunked
    from repro.kernels import ops
    b, h, d = 2, 2, 64
    ns = [512, 1024, 2048, 4096]
    ts = []
    for n in ns:
        q, k, v = _qkv(b, h, n, d)
        f = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
            ops.la_causal(q, k, v, 1.0, 1.0, 128, "xla")),
            argnums=(0, 1, 2)))
        ts.append(_t(f, q, k, v, reps=3))
    slope = np.polyfit(np.log(ns), np.log(ts), 1)[0]
    for n, t1 in zip(ns, ts):
        print(f"fig3,la_bwd_ms_n{n},{t1*1e3:.2f}")
    print(f"fig3,la_bwd_slope_vs_N,{slope:.2f}")

    # residual memory: custom vjp vs plain autodiff through the scan
    n = 2048
    q, k, v = _qkv(b, h, n, d)
    _, vjp_custom = jax.vjp(
        lambda *a: ops.la_causal(*a, 1.0, 1.0, 128, "xla"), q, k, v)
    _, vjp_auto = jax.vjp(
        lambda q, k, v: chunked.la_fwd_chunked(q, k, v, 1.0, 1.0, 128)[0],
        q, k, v)
    custom = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(vjp_custom) if hasattr(x, "size"))
    auto = sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(vjp_auto) if hasattr(x, "size"))
    print(f"fig3,residual_bytes_analytic,{custom}")
    print(f"fig3,residual_bytes_autodiff,{auto}")
    print(f"fig3,residual_ratio_autodiff_over_analytic,{auto/custom:.2f}")


def bench_fig4():
    """Data-movement proxy (paper Fig. 4): HBM traffic per output element
    from the structural HLO model, for ours vs the quadratic LA."""
    from repro.analysis.hlo import total_costs
    from repro.kernels import ops, ref
    b, h, n, d = 2, 2, 2048, 64
    q, k, v = _qkv(b, h, n, d)
    ours = jax.jit(lambda q, k, v: ops.la_causal(
        q, k, v, 1.0, 1.0, 128, "xla")).lower(q, k, v).compile()
    quad = jax.jit(lambda q, k, v: ref.la_ref(
        q, k, v, 1.0, 1.0)).lower(q, k, v).compile()
    ob = total_costs(ours.as_text())["bytes"]
    qb = total_costs(quad.as_text())["bytes"]
    out_elems = b * h * n * d
    # the Pallas TPU kernel's traffic is exact: BlockSpec streams q,k,v
    # once HBM->VMEM, writes o,g once; all state lives in VMEM scratch
    # (the paper's register/shared-memory discipline, adapted)
    pallas_bytes = (3 * b * h * n * d + b * h * n * d + b * h * n) * 4
    print(f"fig4,our_xla_bytes_per_elem,{ob/out_elems:.1f}")
    print(f"fig4,our_pallas_bytes_per_elem,{pallas_bytes/out_elems:.1f}")
    print(f"fig4,quadratic_la_bytes_per_elem,{qb/out_elems:.1f}")
    print(f"fig4,movement_ratio_quad_over_pallas,"
          f"{qb/pallas_bytes:.1f}")


def bench_fig5(steps: int = 30):
    """End-to-end (paper §5.2 at reduced scale): pythia arch trained with
    the paper's LA vs softmax attention — loss curves should track."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.models import model as mdl
    from repro.optim import adamw
    from repro.train.step import build_train_step

    results = {}
    for backend in ("linear", "softmax"):
        cfg = get_config("pythia-1.4b", smoke=True,
                         attention_backend=backend)
        tc = TrainConfig(learning_rate=1e-3, warmup_steps=3,
                         total_steps=steps, checkpoint_every=0)
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        step = jax.jit(build_train_step(cfg, tc))
        data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
        t0 = now()
        losses = []
        for i in range(steps):
            params, opt, m = step(params, opt,
                                  {"tokens": data.batch_at(i)}, i)
            losses.append(float(m["loss"]))
        wall = now() - t0
        results[backend] = (losses, wall)
        print(f"fig5,{backend}_first_loss,{losses[0]:.4f}")
        print(f"fig5,{backend}_final_loss,{losses[-1]:.4f}")
        print(f"fig5,{backend}_wall_s,{wall:.2f}")
    la_final = results["linear"][0][-1]
    sm_final = results["softmax"][0][-1]
    print(f"fig5,final_loss_gap,{abs(la_final-sm_final):.4f}")


def bench_serve(requests: int = 6, max_new: int = 8):
    """Serving engine throughput + the admission story: tokens/s of the
    continuous-batching engine per backend, and how many concurrent
    sequences one byte budget admits for the O(D^2) linear state vs the
    O(S) softmax KV cache (the paper's Table 1 memory ratio, as
    admission control)."""
    import dataclasses

    from repro.configs.registry import get_config
    from repro.models import model as mdl
    from repro.serve.cache import per_slot_bytes
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import ByteBudget

    max_len = 512
    base = get_config("qwen2.5-3b", smoke=True)
    for backend in ("linear", "softmax"):
        cfg = dataclasses.replace(base, attention_backend=backend)
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        engine = Engine(cfg, params, max_slots=4, max_len=max_len)
        for rid in range(requests):
            engine.submit(Request(rid=rid, prompt=list(range(3, 15)),
                                  max_new_tokens=max_new))
        t0 = now()
        done = engine.run()
        dt = now() - t0
        toks = sum(len(v) for v in done.values())
        print(f"serve,{backend}_tokens_per_s,{toks/dt:.1f}")
        print(f"serve,{backend}_per_slot_bytes,"
              f"{per_slot_bytes(cfg, max_len)}")

    budget = 8 * per_slot_bytes(
        dataclasses.replace(base, attention_backend="softmax"), max_len)
    slots = {}
    for backend in ("linear", "softmax"):
        cfg = dataclasses.replace(base, attention_backend=backend)
        slots[backend] = ByteBudget(budget, max_slots=1 << 20) \
            .resolve_slots(cfg, max_len)
        print(f"serve,byte_budget_slots_{backend},{slots[backend]}")
    print(f"serve,admission_ratio_linear_over_softmax,"
          f"{slots['linear']/slots['softmax']:.1f}")


def bench_serve_lat(json_path: str = "artifacts/BENCH_serve.json"):
    """Serving latency distributions (docs/observability.md): run a
    mixed workload — one long prompt amid short chat requests, chunked
    prefill — through the engine with a repro.obs ServeTracer per
    backend family, and record ttft / inter-token p50+p99, queue wait,
    preemption count, and mean slot occupancy.  Under scheduler v2
    (docs/serving.md) each engine step interleaves decode tokens with
    at most a budget's worth of prefill-window tokens, so the long
    prompt no longer runs all its windows in one step and the short
    requests' inter-token p99 stays near p50 (tests/test_obs.py pins
    p99 <= 2x p50 on this exact scenario).

    The *_priority cells exercise preemption: low-priority requests
    reach decode first, then high-priority arrivals evict them — one
    cell per eviction policy family (contiguous snapshot, paged-KV
    drop-and-recompute, gla state-page keep/swap) — so the artifact
    records a non-zero preemption count.

    All numbers are host wall-clock on whatever device runs the bench
    (CPU in CI) — the artifact's contract is the SCHEMA (percentile
    keys present, occupancy + preemptions present), checked by
    tune/bench_check.py, not absolute latency.  Each cell jit-warms its
    engine on the workload's window lengths and then measures from a
    reset tracer, so the percentiles reflect warm scheduling rather
    than one-time compiles."""
    import dataclasses
    import json
    import os

    from repro.configs.registry import get_config
    from repro.models import model as mdl
    from repro.obs import ServeTracer
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import RequestState

    max_len = 64
    base = get_config("qwen2.5-3b", smoke=True)
    # (cell name, attention backend, engine kwargs)
    setups = [("linear", "linear", {}),
              ("gla", "gla", {}),
              ("softmax", "softmax", {}),
              ("paged", "softmax", {"page_size": 16})]
    # mixed workload: rid 0 is the long prompt (7 prefill windows at
    # chunk 5); the short chats admitted alongside stall behind it
    workload = [(0, 34, 8)] + [(rid, 6, 8) for rid in range(1, 6)]
    rng = np.random.default_rng(0)
    prompts = {rid: rng.integers(3, base.vocab_size, size=plen).tolist()
               for rid, plen, _ in workload}
    record = {"device": jax.default_backend(), "kind": "serve_lat",
              "workload": [{"rid": r, "prompt_len": p, "max_new": m}
                           for r, p, m in workload],
              "cells": []}
    def run_cell(name, backend, extra, submit, warm_lens):
        cfg = dataclasses.replace(base, attention_backend=backend)
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        tracer = ServeTracer()
        engine = Engine(cfg, params, max_slots=2, max_len=max_len,
                        eos_id=-1, prefill_chunk=5, tracer=tracer,
                        **extra)
        # jit-warmup: prompts with the same window / fused-completion
        # LENGTHS as the measured workload, then reset the tracer so
        # the cell reports scheduling latency, not compile spikes
        for i, plen in enumerate(warm_lens):
            engine.submit(Request(
                rid=900 + i,
                prompt=rng.integers(3, base.vocab_size,
                                    size=plen).tolist(),
                max_new_tokens=2))
        engine.run()
        tracer.reset()
        submit(engine)
        engine.run()
        s = tracer.summary()
        cell = {"impl": name, "backend": backend,
                "requests": s["requests"], "tokens": s["tokens"],
                "ttft_ms": s["ttft_ms"],
                "inter_token_ms": s["inter_token_ms"],
                "queue_wait_ms": s["queue_wait_ms"],
                "occupancy": s["occupancy"], "steps": s["steps"],
                "preemptions": s["preemptions"]}
        record["cells"].append(cell)
        for metric in ("ttft_ms", "inter_token_ms"):
            for p in ("p50", "p99"):
                print(f"serve_lat,{name}_{metric}_{p},{s[metric][p]}")
        print(f"serve_lat,{name}_occupancy,{s['occupancy']}")
        print(f"serve_lat,{name}_preemptions,{s['preemptions']}")

    def submit_mixed(engine):
        for rid, _, max_new in workload:
            engine.submit(Request(rid=rid, prompt=prompts[rid],
                                  max_new_tokens=max_new))

    for name, backend, extra in setups:
        run_cell(name, backend, extra, submit_mixed, (34, 6))

    # priority-mix cells: the low-priority pair reaches decode first,
    # then the high-priority pair arrives and evicts it — one cell per
    # eviction policy family (docs/serving.md "Scheduler v2"):
    # contiguous snapshot, paged-KV drop-and-recompute, and the gla
    # state-page keep (extra pool pages so the blocker is slots, not
    # pages — a page-blocked gla victim would be demoted to recompute)
    prio_setups = [("linear_priority", "linear", {}),
                   ("paged_priority", "softmax", {"page_size": 16}),
                   ("gla_paged_priority", "gla",
                    {"page_size": 16, "num_pages": 6})]
    low = [(10, 10, 10), (11, 10, 10)]     # (rid, prompt_len, max_new)
    high = [(12, 6, 6), (13, 6, 6)]
    prio_prompts = {
        rid: rng.integers(3, base.vocab_size, size=plen).tolist()
        for rid, plen, _ in low + high}
    record["priority_workload"] = [
        {"rid": r, "prompt_len": p, "max_new": m,
         "priority": 5 if (r, p, m) in high else 0}
        for r, p, m in low + high]

    def submit_priority(engine):
        for rid, _, max_new in low:
            engine.submit(Request(rid=rid, prompt=prio_prompts[rid],
                                  max_new_tokens=max_new))
        # drive the low-priority pair into decode before the
        # high-priority pair lands, so eviction actually happens
        while any(engine.request(rid).state in (RequestState.QUEUED,
                                                RequestState.PREFILLING)
                  for rid, _, _ in low):
            engine.step()
        for rid, _, max_new in high:
            engine.submit(Request(rid=rid, prompt=prio_prompts[rid],
                                  max_new_tokens=max_new, priority=5))

    # (the priority cells' p99 still absorbs first-preemption one-time
    # costs — the snapshot/restore programs and the recompute windows
    # compile on the first eviction, which IS part of the measured run)
    for name, backend, extra in prio_setups:
        run_cell(name, backend, extra, submit_priority, (10, 6))

    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"serve_lat,json_artifact,{json_path}")


def bench_flash(json_path: str = "artifacts/BENCH_flash.json"):
    """Flash v2 acceptance numbers: softmax-baseline forward AND
    forward+backward wall-clock, xla online-softmax scan vs the pallas
    flash kernel, at N ∈ {1024, 4096} with GQA (H=8, Hkv=2, D=64).

    The pallas rows need a TPU; on CPU they are recorded as null and an
    interpret-mode cell at small N checks fwd+bwd parity against the
    scan instead, so the artifact always proves the kernel path runs."""
    import json
    import os

    from repro.kernels import ops

    b, h, hkv, d = 1, 8, 2, 64
    on_tpu = jax.default_backend() == "tpu"
    impls = ["xla"] + (["pallas"] if on_tpu else [])
    record = {"device": jax.default_backend(), "shape":
              {"B": b, "H": h, "Hkv": hkv, "D": d}, "cells": []}

    def qkv(n):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        return (jax.random.normal(ks[0], (b, h, n, d)) * 0.3,
                jax.random.normal(ks[1], (b, hkv, n, d)) * 0.3,
                jax.random.normal(ks[2], (b, hkv, n, d)))

    for n in (1024, 4096):
        q, k, v = qkv(n)
        shape = {"b": b, "h": h, "hkv": hkv, "n": n, "d": d}
        for impl in ("xla", "pallas"):
            if impl not in impls:
                record["cells"].append({"impl": impl, "n": n,
                                        "fwd_ms": None, "fwdbwd_ms": None,
                                        "skipped": "requires TPU",
                                        "roofline": _roof("softmax",
                                                          shape)})
                continue
            fwd = jax.jit(lambda q, k, v, impl=impl: ops.softmax_attention(
                q, k, v, backend=impl))
            fb = jax.jit(jax.grad(
                lambda q, k, v, impl=impl: jnp.sum(ops.softmax_attention(
                    q, k, v, backend=impl)), argnums=(0, 1, 2)))
            t_f = _t(fwd, q, k, v, reps=3)
            t_fb = _t(fb, q, k, v, reps=3)
            print(f"flash,{impl}_fwd_ms_n{n},{t_f*1e3:.2f}")
            print(f"flash,{impl}_fwdbwd_ms_n{n},{t_fb*1e3:.2f}")
            roof = _roof("softmax", shape, t_f)
            print(f"flash,{impl}_roofline_frac_n{n},"
                  f"{roof['achieved_frac']:.4f}")
            record["cells"].append({"impl": impl, "n": n,
                                    "fwd_ms": round(t_f * 1e3, 3),
                                    "fwdbwd_ms": round(t_fb * 1e3, 3),
                                    "roofline": roof})

    # interpret-mode parity cell: fwd+bwd of the flash kernel vs the
    # scan at a CPU-feasible size (this is what CI asserts on)
    n = 128
    q, k, v = qkv(n)
    grads = jax.grad(lambda q, k, v, be: jnp.sum(
        ops.softmax_attention(q, k, v, chunk=64, backend=be) ** 2),
        argnums=(0, 1, 2))
    g_pl = grads(q, k, v, "pallas_interpret")
    g_x = grads(q, k, v, "xla")
    err = max(float(jnp.abs(a - b_).max()) for a, b_ in zip(g_pl, g_x))
    print(f"flash,interpret_bwd_maxerr_n{n},{err:.2e}")
    record["interpret_parity"] = {"n": n, "grad_maxerr": err,
                                  "pass": err < 2e-4}
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"flash,json_artifact,{json_path}")
    if not record["interpret_parity"]["pass"]:
        raise SystemExit(f"flash interpret parity failed: {err}")


def bench_gla(json_path: str = "artifacts/BENCH_gla.json"):
    """Decay-gated LA ("gla" KernelImpl family) acceptance numbers:
    forward AND forward+backward wall-clock, xla chunked scan vs the
    pallas GLA kernel, at N ∈ {1024, 4096} with GQA (H=8, Hkv=2, D=64).

    The pallas rows need a TPU; on CPU they are recorded as null and an
    interpret-mode cell at small N checks fwd+bwd parity against the
    scan instead, so the artifact always proves the kernel path runs."""
    import json
    import os

    from repro.core.numerics import l2_normalize
    from repro.kernels import ops

    b, h, hkv, d = 1, 8, 2, 64
    on_tpu = jax.default_backend() == "tpu"
    impls = ["xla"] + (["pallas"] if on_tpu else [])
    record = {"device": jax.default_backend(), "shape":
              {"B": b, "H": h, "Hkv": hkv, "D": d}, "cells": []}

    def qkvd(n):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        return (l2_normalize(jax.random.normal(ks[0], (b, h, n, d))),
                l2_normalize(jax.random.normal(ks[1], (b, hkv, n, d))),
                jax.random.normal(ks[2], (b, hkv, n, d)),
                -jax.nn.softplus(jax.random.normal(ks[3], (b, hkv, n))))

    for n in (1024, 4096):
        q, k, v, ld = qkvd(n)
        shape = {"b": b, "h": h, "hkv": hkv, "n": n, "d": d}
        for impl in ("xla", "pallas"):
            if impl not in impls:
                record["cells"].append({"impl": impl, "n": n,
                                        "fwd_ms": None, "fwdbwd_ms": None,
                                        "skipped": "requires TPU",
                                        "roofline": _roof("gla", shape)})
                continue
            fwd = jax.jit(lambda q, k, v, ld, impl=impl: ops.gla_causal(
                q, k, v, ld, 1.0, 1.0, 128, impl))
            fb = jax.jit(jax.grad(
                lambda q, k, v, ld, impl=impl: jnp.sum(ops.gla_causal(
                    q, k, v, ld, 1.0, 1.0, 128, impl)),
                argnums=(0, 1, 2, 3)))
            t_f = _t(fwd, q, k, v, ld, reps=3)
            t_fb = _t(fb, q, k, v, ld, reps=3)
            print(f"gla,{impl}_fwd_ms_n{n},{t_f*1e3:.2f}")
            print(f"gla,{impl}_fwdbwd_ms_n{n},{t_fb*1e3:.2f}")
            roof = _roof("gla", shape, t_f)
            print(f"gla,{impl}_roofline_frac_n{n},"
                  f"{roof['achieved_frac']:.4f}")
            record["cells"].append({"impl": impl, "n": n,
                                    "fwd_ms": round(t_f * 1e3, 3),
                                    "fwdbwd_ms": round(t_fb * 1e3, 3),
                                    "roofline": roof})

    # interpret-mode parity cell: fwd+bwd of the pallas GLA kernel vs
    # the scan at a CPU-feasible size (this is what CI asserts on)
    n = 128
    q, k, v, ld = qkvd(n)
    grads = jax.grad(lambda q, k, v, ld, be: jnp.sum(
        ops.gla_causal(q, k, v, ld, 1.0, 1.0, 64, be) ** 2),
        argnums=(0, 1, 2, 3))
    g_pl = grads(q, k, v, ld, "pallas_interpret")
    g_x = grads(q, k, v, ld, "xla")
    err = max(float(jnp.abs(a - b_).max()) for a, b_ in zip(g_pl, g_x))
    print(f"gla,interpret_bwd_maxerr_n{n},{err:.2e}")
    record["interpret_parity"] = {"n": n, "grad_maxerr": err,
                                  "pass": err < 2e-4}
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"gla,json_artifact,{json_path}")
    if not record["interpret_parity"]["pass"]:
        raise SystemExit(f"gla interpret parity failed: {err}")


def bench_paged(json_path: str = "artifacts/BENCH_paged.json"):
    """Paged-KV acceptance numbers: one-token decode throughput over a
    paged cache ("paged" KernelImpl family) vs the contiguous per-slot
    decode ("softmax_decode"), B=4 slots, GQA (H=8, Hkv=2, D=64), at
    context N ∈ {1024, 8192}.

    The compiled-pallas cell needs a TPU; on CPU it is recorded as null
    and an interpret-mode parity cell (paged pallas vs paged xla vs the
    contiguous decode on the gathered layout) proves the kernel path."""
    import json
    import os

    from repro.kernels import ops
    from repro.kernels.paged_attention import gather_pages

    b, h, hkv, d, ps = 4, 8, 2, 64, 16
    on_tpu = jax.default_backend() == "tpu"
    record = {"device": jax.default_backend(),
              "shape": {"B": b, "H": h, "Hkv": hkv, "D": d,
                        "page_size": ps},
              "cells": []}

    def setup(n):
        pmax = n // ps
        num_pages = b * pmax + 1
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, h, 1, d)) * 0.3
        k_pages = jax.random.normal(ks[1], (num_pages, hkv, ps, d)) * 0.3
        v_pages = jax.random.normal(ks[2], (num_pages, hkv, ps, d))
        # each slot owns pmax consecutive pages (sink page last)
        pt = jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
        lens = jnp.full((b,), n, jnp.int32)
        return q, k_pages, v_pages, pt, lens

    for n in (1024, 8192):
        q, kp, vp, pt, lens = setup(n)
        kc, vc = gather_pages(kp, pt), gather_pages(vp, pt)
        shape = {"b": b, "h": h, "hkv": hkv, "n": n, "d": d,
                 "page_size": ps}
        cells = [
            ("contiguous_xla", "softmax_decode",
             jax.jit(lambda q, kc=kc, vc=vc, lens=lens:
                     ops.softmax_decode(q, kc, vc, lens, backend="xla"))),
            ("paged_xla", "paged",
             jax.jit(lambda q, kp=kp, vp=vp, pt=pt, lens=lens:
                     ops.paged_attention(q, kp, vp, pt, lens,
                                         backend="xla"))),
        ]
        for name, family, fn in cells:
            t = _t(fn, q, reps=5)
            print(f"paged,{name}_decode_tokens_per_s_n{n},{b/t:.1f}")
            record["cells"].append({"impl": name, "n": n,
                                    "decode_ms": round(t * 1e3, 3),
                                    "tokens_per_s": round(b / t, 1),
                                    "roofline": _roof(family, shape, t)})
        if on_tpu:
            fn = jax.jit(lambda q, kp=kp, vp=vp, pt=pt, lens=lens:
                         ops.paged_attention(q, kp, vp, pt, lens,
                                             backend="pallas"))
            t = _t(fn, q, reps=5)
            print(f"paged,paged_pallas_decode_tokens_per_s_n{n},{b/t:.1f}")
            record["cells"].append({"impl": "paged_pallas", "n": n,
                                    "decode_ms": round(t * 1e3, 3),
                                    "tokens_per_s": round(b / t, 1),
                                    "roofline": _roof("paged", shape, t)})
        else:
            record["cells"].append({"impl": "paged_pallas", "n": n,
                                    "decode_ms": None,
                                    "tokens_per_s": None,
                                    "skipped": "requires TPU",
                                    "roofline": _roof("paged", shape)})

    # interpret-mode parity cell (what CI asserts on): paged pallas ==
    # paged xla == contiguous decode on the gathered layout
    n = 256
    q, kp, vp, pt, lens = setup(n)
    o_pl = ops.paged_attention(q, kp, vp, pt, lens,
                               backend="pallas_interpret")
    o_x = ops.paged_attention(q, kp, vp, pt, lens, backend="xla")
    o_c = ops.softmax_decode(q, gather_pages(kp, pt), gather_pages(vp, pt),
                             lens, backend="xla")
    err = max(float(jnp.abs(o_pl - o_x).max()),
              float(jnp.abs(o_x - o_c).max()))
    print(f"paged,interpret_parity_maxerr_n{n},{err:.2e}")
    record["interpret_parity"] = {"n": n, "maxerr": err,
                                  "pass": err < 2e-5}
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"paged,json_artifact,{json_path}")
    if not record["interpret_parity"]["pass"]:
        raise SystemExit(f"paged interpret parity failed: {err}")


def bench_decode(json_path: str = "artifacts/BENCH_decode.json"):
    """Fused-decode acceptance numbers: one-token decode tokens/s for
    every family, fused single-kernel step vs the unfused composition,
    at B ∈ {8, 64} with GQA (H=8, Hkv=2, D=64), context N=1024
    (docs/fused_decode.md).

    On CPU the compiled-pallas fused cells need a TPU and are recorded
    as null; the xla fused dispatch IS the byte-identical unfused
    composition (kernels/ops.py registers the same callable), so one
    measurement per (family, B) fills both xla cells — fused >= unfused
    holds by construction, which is exactly the CPU-side claim.  An
    interpret-mode parity block (fused pallas kernel vs the unfused xla
    composition, per family) is what CI asserts on."""
    import json
    import os

    from repro.kernels import ops

    h, hkv, d, ps, n = 8, 2, 64, 16, 1024
    on_tpu = jax.default_backend() == "tpu"
    record = {"device": jax.default_backend(),
              "shape": {"H": h, "Hkv": hkv, "D": d, "N": n,
                        "page_size": ps},
              "cells": []}

    def problems(b):
        """family -> (roofline family, shape, unfused fn, fused fn)."""
        ks = jax.random.split(jax.random.PRNGKey(0), 6)
        qr = jax.random.normal(ks[0], (b, h, d)) * 0.3
        kr = jax.random.normal(ks[1], (b, hkv, d)) * 0.3
        vr = jax.random.normal(ks[2], (b, hkv, d))
        ld = -jax.nn.softplus(jax.random.normal(ks[3], (b, hkv)))
        st = ops.init_state(b, hkv, d, d)
        gst = ops.init_gla_state(b, hkv, d, d)
        q1 = jax.random.normal(ks[0], (b, h, 1, d)) * 0.3
        kc = jax.random.normal(ks[4], (b, hkv, n, d)) * 0.3
        vc = jax.random.normal(ks[5], (b, hkv, n, d))
        lens = jnp.full((b,), n, jnp.int32)
        pmax = n // ps
        num_pages = b * pmax + 1
        kp = kc.transpose(0, 2, 1, 3).reshape(b * pmax, ps, hkv, d) \
            .transpose(0, 2, 1, 3)
        kp = jnp.concatenate([kp, jnp.zeros((1, hkv, ps, d))], 0)
        vp = vc.transpose(0, 2, 1, 3).reshape(b * pmax, ps, hkv, d) \
            .transpose(0, 2, 1, 3)
        vp = jnp.concatenate([vp, jnp.zeros((1, hkv, ps, d))], 0)
        pt = jnp.arange(b * pmax, dtype=jnp.int32).reshape(b, pmax)
        base = {"b": b, "h": h, "hkv": hkv, "n": n, "d": d}

        def mk(fn):
            return jax.jit(fn)
        return {
            "linear": ("linear_decode_fused", dict(base, n=1),
                       mk(lambda q: ops.la_decode_step(st, q, kr, vr,
                                                       1.0, 1.0)[1]),
                       lambda be: mk(lambda q: ops.la_decode_step_fused(
                           st, q, kr, vr, backend=be)[1]),
                       qr),
            "gla": ("gla_decode_fused", dict(base, n=1),
                    mk(lambda q: ops.gla_decode_step(gst, q, kr, vr, ld,
                                                     1.0, 1.0)[1]),
                    lambda be: mk(lambda q: ops.gla_decode_step_fused(
                        gst, q, kr, vr, ld, backend=be)[1]),
                    qr),
            "softmax": ("softmax_decode_fused", base,
                        mk(lambda q: ops.softmax_decode(q, kc, vc, lens,
                                                        backend="xla")),
                        lambda be: mk(lambda q: ops.softmax_decode_fused(
                            q, kc, vc, lens, backend=be)),
                        q1),
            "paged": ("paged_decode_fused",
                      dict(base, page_size=ps),
                      mk(lambda q: ops.paged_attention(
                          q, kp, vp, pt, lens, backend="xla")),
                      lambda be: mk(lambda q: ops.paged_attention_fused(
                          q, kp, vp, pt, lens, backend=be)),
                      q1),
        }

    for b in (8, 64):
        for family, (rfam, shape, unfused, fused_for,
                     q) in problems(b).items():
            t = _t(unfused, q, reps=5)
            tps = round(b / t, 1)
            print(f"decode,{family}_unfused_tokens_per_s_b{b},{tps}")
            record["cells"].append(
                {"impl": f"{family}_unfused_xla", "b": b,
                 "decode_ms": round(t * 1e3, 3), "tokens_per_s": tps,
                 "roofline": _roof(rfam, shape, t)})
            # the xla fused entry point resolves to the SAME unfused
            # callable (registry fallback) — reuse the measurement
            # rather than pretending two timings of one function are a
            # speedup experiment
            print(f"decode,{family}_fused_xla_tokens_per_s_b{b},{tps}")
            record["cells"].append(
                {"impl": f"{family}_fused_xla", "b": b,
                 "decode_ms": round(t * 1e3, 3), "tokens_per_s": tps,
                 "note": "xla fused == unfused composition",
                 "roofline": _roof(rfam, shape, t)})
            if on_tpu:
                fp = fused_for("pallas")
                t_f = _t(fp, q, reps=5)
                tps_f = round(b / t_f, 1)
                print(f"decode,{family}_fused_pallas_tokens_per_s_b{b},"
                      f"{tps_f}")
                record["cells"].append(
                    {"impl": f"{family}_fused_pallas", "b": b,
                     "decode_ms": round(t_f * 1e3, 3),
                     "tokens_per_s": tps_f,
                     "roofline": _roof(rfam, shape, t_f)})
            else:
                record["cells"].append(
                    {"impl": f"{family}_fused_pallas", "b": b,
                     "decode_ms": None, "tokens_per_s": None,
                     "skipped": "requires TPU",
                     "roofline": _roof(rfam, shape)})

    # interpret-mode parity block (what CI asserts on): the fused
    # pallas kernel vs the unfused xla composition, per family
    b = 3
    probs = problems(b)
    err = 0.0
    parity = {}
    for family, (_, _, unfused, fused_for, q) in probs.items():
        o_f = fused_for("pallas_interpret")(q)
        o_u = unfused(q)
        e = float(jnp.abs(o_f - o_u).max())
        parity[family] = e
        err = max(err, e)
        print(f"decode,{family}_interpret_parity_maxerr,{e:.2e}")
    record["interpret_parity"] = {"b": b, "maxerr_per_family": parity,
                                  "maxerr": err, "pass": err < 2e-4}
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"decode,json_artifact,{json_path}")
    if not record["interpret_parity"]["pass"]:
        raise SystemExit(f"fused decode interpret parity failed: {parity}")


def bench_tune(json_path: str = "artifacts/BENCH_autotune.json"):
    """Autotune sweep over every kernel family (repro.tune): measures
    each legal tile candidate through the production dispatch path,
    writes winners to artifacts/tune_cache.json, and emits the full
    candidate x roofline record to artifacts/BENCH_autotune.json.

    On CPU the sweep runs the pallas kernels in interpret mode at small
    N (the winners are interpret-wall-clock, tagged device_kind=cpu and
    never consulted on TPU); on TPU it sweeps the compiled kernels."""
    import json
    import os

    from repro.tune.cache import TuningCache
    from repro.tune.sweep import sweep_shape

    on_tpu = jax.default_backend() == "tpu"
    impl = "pallas" if on_tpu else "pallas_interpret"
    n = 4096 if on_tpu else 256
    shape = {"b": 1, "h": 4, "hkv": 2, "n": n, "d": 32}
    cache = TuningCache.load("artifacts/tune_cache.json")
    records = []
    for family in ("linear", "softmax", "gla", "ssd", "paged",
                   "softmax_decode_fused", "paged_decode_fused"):
        fshape = (dict(shape, page_size=16)
                  if family in ("paged", "paged_decode_fused") else shape)
        records.append(sweep_shape(family, impl, fshape, op="fwd",
                                   reps=3, cache=cache))
        best = records[-1]["best"]
        print(f"tune,{family}_{impl}_best,{best['tiles']}")
        print(f"tune,{family}_{impl}_best_ms,{best['median_ms']}")
    cache.save()
    print(f"tune,cache_entries,{len(cache)}")
    os.makedirs(os.path.dirname(json_path), exist_ok=True)
    with open(json_path, "w") as f:
        json.dump({"device": jax.default_backend(), "sweeps": records},
                  f, indent=1)
    print(f"tune,json_artifact,{json_path}")


def bench_roofline():
    """Emit the roofline tables from the dry-run artifacts."""
    from repro.analysis.roofline import format_table, load_artifacts
    rows = load_artifacts("artifacts/dryrun")
    if not rows:
        print("roofline,artifacts,0  (run python -m repro.launch.dryrun)")
        return
    print(f"roofline,artifacts,{len(rows)}")
    for mesh in ("16x16", "2x16x16"):
        sel = sorted((r for r in rows if r["mesh"] == mesh),
                     key=lambda r: (r["arch"], r["shape"]))
        if sel:
            print(f"--- mesh {mesh} ---")
            print(format_table(sel))


BENCHES = {"table1": bench_table1, "fig2": bench_fig2, "fig3": bench_fig3,
           "fig4": bench_fig4, "fig5": bench_fig5, "serve": bench_serve,
           "serve_lat": bench_serve_lat,
           "flash": bench_flash, "gla": bench_gla, "paged": bench_paged,
           "decode": bench_decode, "tune": bench_tune,
           "roofline": bench_roofline}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    for name in names:
        print(f"# === {name} ===")
        BENCHES[name]()


if __name__ == "__main__":
    main()
