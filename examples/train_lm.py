"""End-to-end driver (paper §5.2 at CPU scale): train the paper's
pythia-1.4b architecture — reduced width — for a few hundred steps with
the linear-attention backend, through the full production stack
(data pipeline -> jitted train step -> fault-tolerant Trainer with
checkpointing).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import model as mdl
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("pythia-1.4b", smoke=True)   # paper's e2e arch
    print(f"arch={cfg.name} backend={cfg.attention_backend} "
          f"params={sum(x.size for x in jax.tree.leaves(mdl.init_params(cfg, jax.random.PRNGKey(0))))/1e6:.1f}M")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(learning_rate=1e-3, min_learning_rate=5e-5,
                         warmup_steps=args.steps // 10,
                         total_steps=args.steps,
                         checkpoint_every=args.steps // 3,
                         checkpoint_dir=ckpt_dir)
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
        trainer = Trainer(cfg, tc, params, data)
        hist = trainer.run(args.steps)

    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"loss: {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({trainer.monitor.flagged} straggler steps)")
    assert last < first, "training must converge"
    print("OK")


if __name__ == "__main__":
    main()
