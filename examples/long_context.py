"""Long-context decode: why linear attention owns the long_500k cell.

Decodes with a context counter at 500k+ tokens: per-token cost and state
size are both independent of context length — the quadratic-attention
equivalent would need a 500k-entry KV cache and O(N) work per token.

    PYTHONPATH=src python examples/long_context.py
"""
from repro.tune.timer import now

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models import model as mdl
from repro.serve.cache import cache_bytes, kv_cache_bytes_analytic

cfg = get_config("qwen2.5-3b", smoke=True)
params = mdl.init_params(cfg, jax.random.PRNGKey(0))

# build a cache, teleport its position counter to half a million tokens
cache = mdl.init_cache(cfg, batch=1, max_len=1 << 20)
prompt = jnp.arange(1, 33, dtype=jnp.int32)[None]
logits, cache = mdl.prefill(params, cfg, {"tokens": prompt}, cache)
cache["pos"] = jnp.full_like(cache["pos"], 524_288)

decode = jax.jit(lambda p, c, t: mdl.decode_step(p, cfg, c, t))
tokens = jnp.asarray([5], jnp.int32)
logits, cache = decode(params, cache, tokens)  # compile

t0 = now()
steps = 50
for _ in range(steps):
    logits, cache = decode(params, cache, tokens)
jax.block_until_ready(logits)
dt = (now() - t0) / steps

la_bytes = cache_bytes(cfg, 1, 1 << 20)
kv_bytes = kv_cache_bytes_analytic(
    get_config("qwen2.5-3b"), batch=1, seq=524_288)
print(f"per-token decode at ctx=524288: {dt*1e3:.2f} ms (reduced config)")
print(f"LA state bytes (this config):     {la_bytes:,}")
print(f"softmax KV cache at 524k (full):  {kv_bytes:,} "
      f"({kv_bytes/1e9:.1f} GB)")
print("OK")
