"""Quickstart: the paper's linear attention as a drop-in module.

Shows the three public entry points — full-sequence (training), prefill
and O(D^2)-per-token decode — and checks them against each other.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.linear_attention import LACfg, la_attention, \
    la_attention_decode, la_attention_prefill

B, H, HKV, N, D = 2, 8, 2, 256, 64   # GQA: 8 query heads, 2 KV heads

key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, H, N, D))
k = jax.random.normal(kk, (B, HKV, N, D))
v = jax.random.normal(kv, (B, HKV, N, D))

cfg = LACfg(a=1.0, b=1.0, normalize_qk=True, chunk=128)

# 1. training path: causal, custom analytic backward (paper Eqs. 19-21)
o = la_attention(q, k, v, cfg)
print("train-path output:", o.shape, o.dtype)

grads = jax.grad(lambda q, k, v: jnp.sum(la_attention(q, k, v, cfg) ** 2),
                 argnums=(0, 1, 2))(q, k, v)
print("grad norms:", [float(jnp.linalg.norm(g)) for g in grads])

# 2. serving: prefill the prompt, then decode token by token.
#    The state is (B, HKV, D, D+1) — independent of context length.
o_prefill, state = la_attention_prefill(q[:, :, :200], k[:, :, :200],
                                        v[:, :, :200], cfg)
print("prefill state:", state.s.shape, "(constant in N — paper's claim)")

for i in range(200, N):
    state, o_i = la_attention_decode(state, q[:, :, i], k[:, :, i],
                                     v[:, :, i], cfg)
err = float(jnp.abs(o_i[:, :, None] - o[:, :, -1:]).max())
print(f"decode vs full-sequence max err: {err:.2e}")
assert err < 1e-3
print("OK")
