"""Batched serving through the request-lifecycle engine (serving v2).

Requests share slots resolved by a BYTE BUDGET: each linear-backend
slot's memory is the paper's O(D^2) recurrent state (independent of
generation length), so a budget that fits a handful of softmax KV
caches admits dozens of linear slots.  One request samples with its own
temperature/seed; outputs stream per token with lifecycle states.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as mdl
from repro.serve.cache import cache_bytes, per_slot_bytes
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams
from repro.serve.scheduler import ByteBudget

cfg = get_config("qwen2.5-3b", smoke=True)
tok = ByteTokenizer()
params = mdl.init_params(cfg, jax.random.PRNGKey(0))

print(f"decode cache @ 1k ctx:  {cache_bytes(cfg, 4, 1024):,} bytes")
print(f"decode cache @ 64k ctx: {cache_bytes(cfg, 4, 65536):,} bytes "
      f"(identical — the paper's O(D^2) state)")

budget = 4 * per_slot_bytes(cfg, 256)  # pays for 4 linear slots exactly
engine = Engine(cfg, params, max_len=256, eos_id=-1,
                policy=ByteBudget(budget), prefill_chunk=8)
print(f"byte budget {budget:,} -> {engine.num_slots} linear slots "
      f"({per_slot_bytes(cfg, 256):,} bytes/slot)")

prompts = ["hello world", "linear attention", "tpu kernels",
           "prefix sums", "state space"]
for rid, text in enumerate(prompts):
    ids = [t % cfg.vocab_size for t in tok.encode(text)]
    sampling = SamplingParams(temperature=0.8, top_k=40, seed=rid) \
        if rid == 0 else SamplingParams()  # request 0 samples, rest greedy
    engine.submit(Request(rid=rid, prompt=ids, max_new_tokens=8,
                          sampling=sampling))

for out in engine.stream():
    if out.finished:
        print(f"request {out.rid} finished ({out.finish_reason}): "
              f"{engine.request(out.rid).generated}")
print("OK")
