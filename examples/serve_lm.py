"""Batched serving with continuous batching.

Five requests share two engine slots; each slot's memory is the paper's
O(D^2) recurrent state, so generation length never grows the footprint.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as mdl
from repro.serve.cache import cache_bytes
from repro.serve.engine import Engine, Request

cfg = get_config("qwen2.5-3b", smoke=True)
tok = ByteTokenizer()
params = mdl.init_params(cfg, jax.random.PRNGKey(0))

print(f"decode cache @ 1k ctx:  {cache_bytes(cfg, 4, 1024):,} bytes")
print(f"decode cache @ 64k ctx: {cache_bytes(cfg, 4, 65536):,} bytes "
      f"(identical — the paper's O(D^2) state)")

engine = Engine(cfg, params, max_slots=2, max_len=256, eos_id=-1)
prompts = ["hello world", "linear attention", "tpu kernels",
           "prefix sums", "state space"]
for rid, text in enumerate(prompts):
    ids = [t % cfg.vocab_size for t in tok.encode(text)]
    engine.submit(Request(rid=rid, prompt=ids, max_new_tokens=8))

done = engine.run()
for rid in sorted(done):
    print(f"request {rid}: prompt={prompts[rid]!r} -> "
          f"{len(done[rid])} tokens {done[rid]}")
print("OK")
